//! Rendering of analyses: text tables (the `repro` harness building
//! blocks) and JSON export.

use std::collections::BTreeMap;

use crate::kernels::family::Family;
use crate::taxbreak::{Analysis, Decomposition};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::{ms, ratio, us, Table};

/// Render the decomposition as a single-row summary table.
pub fn decomposition_table(title: &str, d: &Decomposition) -> Table {
    let mut t = Table::new(
        title,
        &[
            "kernels", "T_Py(ms)", "T_base(ms)", "dCT(ms)", "dKT(ms)",
            "T_orch(ms)", "T_dev(ms)", "HDBI", "idle",
        ],
    );
    t.row(vec![
        d.n_kernels.to_string(),
        ms(d.t_py_us / 1000.0),
        ms(d.t_base_us / 1000.0),
        ms(d.dct_us / 1000.0),
        ms(d.dkt_us / 1000.0),
        ms(d.orchestration_us() / 1000.0),
        ms(d.device_active_us / 1000.0),
        ratio(d.hdbi()),
        format!("{:.1}%", 100.0 * d.idle_fraction()),
    ]);
    t
}

/// Per-device decomposition table (multi-device traces: one row per
/// rank; the totals row is the aggregate the slices partition).
pub fn per_device_table(title: &str, d: &Decomposition) -> Table {
    let mut t = Table::new(
        title,
        &[
            "device", "kernels", "T_Py(ms)", "T_base(ms)", "dCT(ms)", "dKT(ms)",
            "T_orch(ms)", "T_dev(ms)", "HDBI",
        ],
    );
    for (dev, s) in &d.per_device {
        t.row(vec![
            format!("dev {dev}"),
            s.invocations.to_string(),
            ms(s.t_py_us / 1000.0),
            ms(s.t_base_us / 1000.0),
            ms(s.dct_us / 1000.0),
            ms(s.dkt_us / 1000.0),
            ms(s.orchestration_us() / 1000.0),
            ms(s.device_active_us / 1000.0),
            ratio(s.hdbi()),
        ]);
    }
    t.row(vec![
        "total".to_string(),
        d.n_kernels.to_string(),
        ms(d.t_py_us / 1000.0),
        ms(d.t_base_us / 1000.0),
        ms(d.dct_us / 1000.0),
        ms(d.dkt_us / 1000.0),
        ms(d.orchestration_us() / 1000.0),
        ms(d.device_active_us / 1000.0),
        ratio(d.hdbi()),
    ]);
    t
}

/// Per-family launch-latency table (Table IV layout): p50/p95 of
/// T_launch and ΔKT_fw = p50 − floor.
pub fn family_launch_table(title: &str, a: &Analysis) -> Table {
    let mut per_family: BTreeMap<&str, Vec<&crate::taxbreak::phase2::KernelReplay>> =
        BTreeMap::new();
    for k in a.phase2.kernels.values() {
        per_family.entry(k.meta.family.as_str()).or_default().push(k);
    }
    let mut t = Table::new(title, &["Kernel Family", "p50", "p95", "dKT_fw", "%"]);
    let floor = a.phase2.floor.p50;
    t.row(vec![
        "Tfloor (null)".to_string(),
        us(floor),
        us(a.phase2.floor.p95),
        "-".to_string(),
        "-".to_string(),
    ]);
    for fam in Family::table4_rows() {
        let Some(entries) = per_family.get(fam.tag()) else {
            continue;
        };
        // Invocation-weighted pooled launch distribution.
        let mut p50s: Vec<f64> = Vec::new();
        let mut p95s: Vec<f64> = Vec::new();
        for e in entries {
            p50s.push(e.t_launch.p50);
            p95s.push(e.t_launch.p95);
        }
        let p50 = Summary::of(&p50s).p50;
        let p95 = Summary::of(&p95s).p95;
        let dkt_fw = (p50 - floor).max(0.0);
        t.row(vec![
            fam.label().to_string(),
            us(p50),
            us(p95),
            us(dkt_fw),
            format!("{:.0}%", 100.0 * dkt_fw / floor),
        ]);
    }
    t
}

/// JSON export of a full analysis (for downstream tooling / plotting).
pub fn to_json(a: &Analysis) -> Json {
    let d = &a.decomposition;
    let mut families = Json::obj();
    for (fam, s) in &d.per_family {
        families.set(
            fam,
            Json::obj()
                .with("invocations", s.invocations)
                .with("t_py_us", s.t_py_us)
                .with("t_base_us", s.t_base_us)
                .with("dct_us", s.dct_us)
                .with("dkt_us", s.dkt_us)
                .with("device_us", s.device_us),
        );
    }
    Json::obj()
        .with(
            "decomposition",
            Json::obj()
                .with("n_kernels", d.n_kernels)
                .with("t_py_us", d.t_py_us)
                .with("t_base_us", d.t_base_us)
                .with("dft_us", d.dft_us())
                .with("dct_us", d.dct_us)
                .with("dkt_us", d.dkt_us)
                .with("orchestration_us", d.orchestration_us())
                .with("device_active_us", d.device_active_us)
                .with("e2e_us", d.e2e_us)
                .with("hdbi", d.hdbi())
                .with("idle_fraction", d.idle_fraction())
                .with("per_family", families)
                .with("per_device", {
                    let mut devices = Vec::with_capacity(d.per_device.len());
                    for (dev, s) in &d.per_device {
                        devices.push(
                            Json::obj()
                                .with("device", *dev)
                                .with("invocations", s.invocations)
                                .with("t_py_us", s.t_py_us)
                                .with("t_base_us", s.t_base_us)
                                .with("dct_us", s.dct_us)
                                .with("dkt_us", s.dkt_us)
                                .with("orchestration_us", s.orchestration_us())
                                .with("device_active_us", s.device_active_us)
                                .with("hdbi", s.hdbi()),
                        );
                    }
                    Json::Arr(devices)
                }),
        )
        .with(
            "phase2",
            Json::obj()
                .with("floor_mean_us", a.phase2.floor.mean)
                .with("floor_p50_us", a.phase2.floor.p50)
                .with("dispatch_base_us", a.phase2.dispatch_base_us)
                .with("unique_kernels", a.phase2.kernels.len())
                .with("cache_hits", a.phase2.cache_hits),
        )
        .with(
            "baselines",
            Json::obj()
                .with("framework_tax_us", a.baselines.framework_tax_us)
                .with("tklqt_us", a.baselines.tklqt_us)
                .with("queue_share", a.baselines.queue_share),
        )
        .with("diagnosis", {
            let mut dj = Json::obj()
                .with("hdbi", a.diagnosis.hdbi)
                .with("host_bound", a.diagnosis.host_bound)
                .with("target", a.diagnosis.target.as_str())
                .with("rationale", a.diagnosis.rationale.as_str());
            if let Some(q) = &a.diagnosis.quantified {
                dj.set(
                    "quantified",
                    Json::obj()
                        .with("counterfactual", q.counterfactual.as_str())
                        .with("orch_reduction", q.orch_reduction)
                        .with("e2e_reduction", q.e2e_reduction),
                );
            }
            dj
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};
    use crate::taxbreak::{analyze, ReplayConfig, SimReplayBackend};

    fn analysis() -> Analysis {
        let platform = Platform::h100();
        let trace = simulate(
            &models::llama_1b(),
            &platform,
            &Workload::prefill(1, 128),
            21,
        );
        let mut backend = SimReplayBackend::new(platform, 22);
        analyze(&trace, &mut backend, &ReplayConfig::fast())
    }

    #[test]
    fn tables_render() {
        let a = analysis();
        let t1 = decomposition_table("demo", &a.decomposition);
        assert!(t1.render().contains("HDBI"));
        let t2 = family_launch_table("Table IV", &a);
        let rendered = t2.render();
        assert!(rendered.contains("Tfloor (null)"));
        assert!(rendered.contains("GEMM (cuBLAS)"));
        assert!(rendered.contains("Reduce"));
        // Llama's GEMMs are all cuBLAS-routed, so the nvjet row is
        // absent; floor + ≥3 family rows must render.
        assert!(t2.n_rows() >= 4, "rows={}", t2.n_rows());
    }

    #[test]
    fn json_exports_and_parses() {
        let a = analysis();
        let j = to_json(&a);
        let text = j.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.req("decomposition").unwrap().usize_of("n_kernels").unwrap(),
            a.decomposition.n_kernels
        );
        assert!(back.req("phase2").unwrap().f64_of("floor_mean_us").unwrap() > 4.0);
        let devices = back
            .req("decomposition")
            .unwrap()
            .arr_of("per_device")
            .unwrap();
        assert_eq!(devices.len(), 1, "single-device trace: one slice");
        assert!(devices[0].f64_of("hdbi").unwrap() > 0.0);
    }

    #[test]
    fn per_device_table_renders_slices_and_total() {
        let a = analysis();
        let t = per_device_table("per-device", &a.decomposition);
        let rendered = t.render();
        assert!(rendered.contains("dev 0"));
        assert!(rendered.contains("total"));
        assert_eq!(t.n_rows(), 2);
    }
}
