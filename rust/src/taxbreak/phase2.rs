//! Phase 2: isolation replay (paper §III-B).
//!
//! First a null-kernel run measures the dynamic system floor
//! `T_sys_floor`; then each unique kernel-database entry is replayed in
//! isolation (NVTX-scoped, serialized with a device sync so no queue
//! overlap), measuring per invocation:
//!
//! ```text
//! T_dispatch = t_api − t_nvtx      (host dispatch: ATen + library FE)
//! T_launch   = t_kernel − t_api    (launch gap)
//! ```
//!
//! Entries sharing identical ATen metadata + kernel name + launch
//! config are deduplicated via a global cache so only uncached entries
//! are profiled ("saving significant runtime").  The dispatch baseline
//! (Eq. 7) is the *median* `T_dispatch` of framework-native kernels;
//! `ΔCT = max(0, T_dispatch − T_dispatch_base)` (Eq. 8).

use std::collections::HashMap;

use crate::hardware::Platform;
use crate::host::HostModel;
use crate::kernels::database::KernelEntry;
use crate::kernels::family::Family;
use crate::kernels::KernelDb;
use crate::taxbreak::matching::{self, MatchKind};
use crate::trace::{DedupKey, KernelMeta};
use crate::util::rng::Rng;
use crate::util::stats::{self, Summary};

/// Replay protocol parameters (paper §IV: W=50 warm-up, R=150 runs).
#[derive(Debug, Clone, Copy)]
pub struct ReplayConfig {
    pub warmup: usize,
    pub runs: usize,
}

impl ReplayConfig {
    pub fn paper() -> ReplayConfig {
        ReplayConfig {
            warmup: 50,
            runs: 150,
        }
    }

    /// Reduced protocol for tests and quick sweeps.
    pub fn fast() -> ReplayConfig {
        ReplayConfig {
            warmup: 2,
            runs: 20,
        }
    }
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig::paper()
    }
}

/// Raw measurements of one replayed kernel.
#[derive(Debug, Clone, Default)]
pub struct ReplayMeasurement {
    /// Per-run host dispatch time (nvtx → api), us.
    pub t_dispatch_us: Vec<f64>,
    /// Per-run launch gap (api → kernel start), us.
    pub t_launch_us: Vec<f64>,
    /// Kernel symbol the replay actually dispatched (autotuning may
    /// pick a variant of the traced kernel).
    pub observed_name: String,
}

/// Something that can replay kernels in isolation: the simulator
/// ([`SimReplayBackend`]) or the real PJRT runtime
/// (`runtime::PjrtReplayBackend`).  Phase 2 is backend-agnostic —
/// trace-format-as-interface (DESIGN.md §9).
pub trait ReplayBackend {
    /// Replay `entry` for `cfg.runs` measured runs after `cfg.warmup`.
    fn replay(&mut self, entry: &KernelEntry, cfg: &ReplayConfig) -> ReplayMeasurement;

    /// Null-kernel floor runs (`T_launch` of an empty kernel).
    fn null_kernel(&mut self, cfg: &ReplayConfig) -> Vec<f64>;
}

/// Per-unique-kernel Phase-2 result.
#[derive(Debug, Clone)]
pub struct KernelReplay {
    pub meta: KernelMeta,
    pub invocations: usize,
    /// Mean host dispatch (ATen + library front-end), us.
    pub t_dispatch_us: f64,
    /// Launch-gap distribution, us.
    pub t_launch: Summary,
    /// ΔCT = max(0, T_dispatch − T_dispatch_base)  (Eq. 8).
    pub dct_us: f64,
    /// How the replayed kernel was matched to the traced one (Eq. 9).
    pub match_kind: MatchKind,
}

/// Phase-2 output.
#[derive(Debug, Clone)]
pub struct Phase2Result {
    /// dedup key → replay measurements.
    pub kernels: HashMap<DedupKey, KernelReplay>,
    /// Null-kernel floor distribution (Table III).
    pub floor: Summary,
    /// Eq. 7 dispatch baseline: median T_dispatch of framework-native
    /// kernels.
    pub dispatch_base_us: f64,
    /// Entries skipped thanks to the global dedup cache.
    pub cache_hits: usize,
    /// Entries actually profiled.
    pub profiled: usize,
}

impl Phase2Result {
    pub fn replay_of(&self, key: DedupKey) -> Option<&KernelReplay> {
        self.kernels.get(&key)
    }
}

/// Run Phase 2 over a kernel database with an optional pre-populated
/// global cache (`seed_cache`) of already-profiled dedup keys.
pub fn run_with_cache(
    db: &KernelDb,
    backend: &mut dyn ReplayBackend,
    cfg: &ReplayConfig,
    seed_cache: &mut HashMap<DedupKey, KernelReplay>,
) -> Phase2Result {
    // Null-kernel floor first (dynamic system floor).
    let floor_runs = backend.null_kernel(cfg);
    let floor = Summary::of(&floor_runs);

    let mut kernels: HashMap<DedupKey, KernelReplay> = HashMap::new();
    let mut cache_hits = 0usize;
    let mut profiled = 0usize;
    let mut dispatch_native: Vec<f64> = Vec::new();

    for entry in db.entries() {
        let key = entry.meta.dedup();
        if let Some(cached) = seed_cache.get(&key) {
            cache_hits += 1;
            let mut k = cached.clone();
            k.invocations = entry.invocations;
            if !k.meta.lib_mediated {
                dispatch_native.push(k.t_dispatch_us);
            }
            kernels.insert(key, k);
            continue;
        }
        profiled += 1;
        let m = backend.replay(entry, cfg);
        let t_dispatch = stats::mean(&m.t_dispatch_us);
        let t_launch = Summary::of(&m.t_launch_us);
        let match_kind = matching::match_kernel(&m.observed_name, &entry.meta.kernel_name);
        if !entry.meta.lib_mediated {
            dispatch_native.push(t_dispatch);
        }
        let replay = KernelReplay {
            meta: entry.meta.clone(),
            invocations: entry.invocations,
            t_dispatch_us: t_dispatch,
            t_launch,
            dct_us: 0.0, // filled once the baseline is known
            match_kind,
        };
        seed_cache.insert(key, replay.clone());
        kernels.insert(key, replay);
    }

    // Eq. 7: baseline = median dispatch of framework-native kernels.
    let dispatch_base_us = stats::median(&dispatch_native);
    // Eq. 8: ΔCT for library-mediated kernels.
    for k in kernels.values_mut() {
        k.dct_us = if k.meta.lib_mediated {
            (k.t_dispatch_us - dispatch_base_us).max(0.0)
        } else {
            0.0
        };
    }
    for k in seed_cache.values_mut() {
        if k.meta.lib_mediated {
            k.dct_us = (k.t_dispatch_us - dispatch_base_us).max(0.0);
        }
    }

    Phase2Result {
        kernels,
        floor,
        dispatch_base_us,
        cache_hits,
        profiled,
    }
}

/// Run Phase 2 with a fresh cache.
pub fn run(db: &KernelDb, backend: &mut dyn ReplayBackend, cfg: &ReplayConfig) -> Phase2Result {
    let mut cache = HashMap::new();
    run_with_cache(db, backend, cfg, &mut cache)
}

/// Simulator-backed replay: draws from the same host/launch
/// distributions the full-model simulation used, queue-free (each
/// replay is serialized with a sync, exactly the paper's protocol).
#[derive(Debug, Clone)]
pub struct SimReplayBackend {
    host: HostModel,
    rng: Rng,
    /// Probability that autotuning picks a variant symbol on replay —
    /// exercises the Eq. 9 fallback hierarchy.
    pub variant_prob: f64,
}

impl SimReplayBackend {
    pub fn new(platform: Platform, seed: u64) -> SimReplayBackend {
        SimReplayBackend {
            host: HostModel::new(platform),
            rng: Rng::new(seed).fork_str("phase2-replay"),
            variant_prob: 0.08,
        }
    }
}

impl ReplayBackend for SimReplayBackend {
    fn replay(&mut self, entry: &KernelEntry, cfg: &ReplayConfig) -> ReplayMeasurement {
        let family = Family::from_tag(&entry.meta.family).expect("valid family tag");
        let mut stream = self.rng.fork_str(&entry.meta.dedup_key());
        // Warm-up draws advance the stream but are discarded —
        // mirrors the W warm-up iterations of the protocol.
        for _ in 0..cfg.warmup {
            let _ = self.host.sample(family, &mut stream);
        }
        let mut m = ReplayMeasurement {
            observed_name: if stream.next_f64() < self.variant_prob {
                format!("{}_v2", entry.meta.kernel_name)
            } else {
                entry.meta.kernel_name.to_string()
            },
            ..Default::default()
        };
        for _ in 0..cfg.runs {
            let s = self.host.sample(family, &mut stream);
            // NVTX opens at the ATen boundary: dispatch = base + ΔCT.
            m.t_dispatch_us.push(s.t_base + s.t_ct);
            m.t_launch_us.push(s.launch_gap);
        }
        m
    }

    fn null_kernel(&mut self, cfg: &ReplayConfig) -> Vec<f64> {
        let mut stream = self.rng.fork_str("null-kernel");
        for _ in 0..cfg.warmup {
            let _ = self.host.sample_floor(&mut stream);
        }
        (0..cfg.runs)
            .map(|_| self.host.sample_floor(&mut stream))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};
    use crate::taxbreak::phase1::Phase1;

    fn phase2_for(model: &crate::models::ModelSpec, platform: Platform) -> (Phase1, Phase2Result) {
        let trace = simulate(model, &platform, &Workload::prefill(1, 128), 5);
        let p1 = Phase1::from_trace(&trace);
        let mut backend = SimReplayBackend::new(platform, 11);
        let p2 = run(&p1.db, &mut backend, &ReplayConfig::fast());
        (p1, p2)
    }

    #[test]
    fn floor_matches_table3() {
        let (_, p2) = phase2_for(&models::gpt2(), Platform::h100());
        assert!((p2.floor.mean - 4.72).abs() < 0.15, "floor {}", p2.floor.mean);
        assert!(p2.floor.p5 < p2.floor.p50 && p2.floor.p50 < p2.floor.p95);
        let (_, p2) = phase2_for(&models::gpt2(), Platform::h200());
        assert!((p2.floor.mean - 4.503).abs() < 0.15, "floor {}", p2.floor.mean);
    }

    #[test]
    fn every_db_entry_gets_replayed() {
        let (p1, p2) = phase2_for(&models::llama_1b(), Platform::h100());
        assert_eq!(p2.kernels.len(), p1.db.len());
        assert_eq!(p2.profiled, p1.db.len());
        assert_eq!(p2.cache_hits, 0);
    }

    #[test]
    fn dct_zero_for_framework_native_positive_for_cublas() {
        let (_, p2) = phase2_for(&models::llama_1b(), Platform::h100());
        let mut saw_lib = false;
        for k in p2.kernels.values() {
            if k.meta.lib_mediated {
                saw_lib = true;
                assert!(k.dct_us > 0.0, "cuBLAS kernel must carry ΔCT");
            } else {
                assert_eq!(k.dct_us, 0.0);
            }
        }
        assert!(saw_lib);
    }

    #[test]
    fn gpt2_has_zero_dct_everywhere() {
        // §V-C: GPT-2's GEMMs are framework-native => ΔCT == 0.
        let (_, p2) = phase2_for(&models::gpt2(), Platform::h200());
        for k in p2.kernels.values() {
            assert_eq!(k.dct_us, 0.0);
        }
    }

    #[test]
    fn dispatch_base_is_cpu_scaled() {
        let (_, a) = phase2_for(&models::gpt2(), Platform::h100());
        let (_, b) = phase2_for(&models::gpt2(), Platform::h200());
        let ratio = b.dispatch_base_us / a.dispatch_base_us;
        assert!((ratio - 1.0 / 1.30).abs() < 0.06, "ratio {ratio}");
    }

    #[test]
    fn launch_exceeds_floor_for_gemms() {
        // Table IV: GEMM families sit well above the floor.
        let (_, p2) = phase2_for(&models::llama_1b(), Platform::h100());
        for k in p2.kernels.values() {
            if k.meta.family == "gemm_cublas" {
                let dkt_fw = k.t_launch.p50 - p2.floor.p50;
                assert!(
                    dkt_fw > 1.0,
                    "cuBLAS ΔKT_fw {dkt_fw} should be ≈1.88us"
                );
            }
        }
    }

    #[test]
    fn global_cache_skips_profiled_entries() {
        let platform = Platform::h100();
        let trace = simulate(&models::gpt2(), &platform, &Workload::prefill(1, 128), 5);
        let p1 = Phase1::from_trace(&trace);
        let mut backend = SimReplayBackend::new(platform, 11);
        let mut cache = HashMap::new();
        let first = run_with_cache(&p1.db, &mut backend, &ReplayConfig::fast(), &mut cache);
        assert_eq!(first.cache_hits, 0);
        let second = run_with_cache(&p1.db, &mut backend, &ReplayConfig::fast(), &mut cache);
        assert_eq!(second.profiled, 0);
        assert_eq!(second.cache_hits, p1.db.len());
    }

    #[test]
    fn some_replays_hit_variant_matching() {
        let (_, p2) = phase2_for(&models::olmoe(), Platform::h100());
        let exact = p2
            .kernels
            .values()
            .filter(|k| k.match_kind == MatchKind::Exact)
            .count();
        // Most are exact; variants exercise the fallback path.
        assert!(exact as f64 > 0.7 * p2.kernels.len() as f64);
    }

    #[test]
    fn replay_is_deterministic() {
        let (_, a) = phase2_for(&models::gpt2(), Platform::h100());
        let (_, b) = phase2_for(&models::gpt2(), Platform::h100());
        assert_eq!(a.dispatch_base_us, b.dispatch_base_us);
        assert_eq!(a.floor.mean, b.floor.mean);
    }
}
