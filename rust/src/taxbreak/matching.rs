//! Kernel matching (paper Eq. 9).
//!
//! Phase-2 replay may dispatch a *variant* of the traced kernel
//! (autotuning picks a different tile/stage configuration for the
//! isolated shape).  After narrowing candidates to the target
//! neighborhood, the final kernel resolves through a name-based
//! fallback hierarchy over cleaned (canonical) names:
//!
//! ```text
//! exact        n_replay == n_trace
//! substring    n_replay ⊆ n_trace  or  n_trace ⊆ n_replay
//! most-frequent  otherwise
//! ```

/// How a replayed kernel was matched back to the traced kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MatchKind {
    Exact,
    Substring,
    MostFrequent,
}

impl MatchKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MatchKind::Exact => "exact",
            MatchKind::Substring => "substring",
            MatchKind::MostFrequent => "most-frequent",
        }
    }
}

/// Clean a raw kernel symbol to its canonical name: strip template
/// arguments, trailing digits-only variant suffixes and whitespace.
pub fn clean_name(raw: &str) -> String {
    let mut s = raw.trim();
    // Strip template arguments.
    if let Some(i) = s.find('<') {
        s = &s[..i];
    }
    // Strip trailing `_v<digits>` / `_<digits>` variant suffixes.
    let mut out = s.to_string();
    loop {
        let Some(pos) = out.rfind('_') else { break };
        let tail = &out[pos + 1..];
        let is_variant =
            !tail.is_empty() && (tail.chars().all(|c| c.is_ascii_digit())
                || (tail.starts_with('v') && tail[1..].chars().all(|c| c.is_ascii_digit()) && tail.len() > 1));
        if is_variant {
            out.truncate(pos);
        } else {
            break;
        }
    }
    out
}

/// Resolve a replayed kernel against the traced target (Eq. 9).
///
/// `most_frequent` is the fallback candidate: the most frequently
/// invoked kernel in the replay neighborhood.
pub fn match_kernel(replay_name: &str, trace_name: &str) -> MatchKind {
    let r = clean_name(replay_name);
    let t = clean_name(trace_name);
    if r == t {
        MatchKind::Exact
    } else if r.contains(&t) || t.contains(&r) {
        MatchKind::Substring
    } else {
        MatchKind::MostFrequent
    }
}

/// Pick the best match for `trace_name` among `candidates`
/// (names paired with invocation frequency). Returns the winning index
/// and its match kind; falls back to the most frequent candidate.
pub fn resolve<'a>(
    trace_name: &str,
    candidates: &[(&'a str, usize)],
) -> Option<(usize, MatchKind)> {
    if candidates.is_empty() {
        return None;
    }
    let mut best: Option<(usize, MatchKind)> = None;
    for (i, (name, _)) in candidates.iter().enumerate() {
        let kind = match_kernel(name, trace_name);
        let rank = |k: MatchKind| match k {
            MatchKind::Exact => 0,
            MatchKind::Substring => 1,
            MatchKind::MostFrequent => 2,
        };
        match best {
            Some((_, b)) if rank(kind) >= rank(b) => {}
            _ => best = Some((i, kind)),
        }
        if kind == MatchKind::Exact {
            break;
        }
    }
    let (i, kind) = best.unwrap();
    if kind == MatchKind::MostFrequent {
        // Fall back to the highest-frequency candidate.
        let (mf, _) = candidates
            .iter()
            .enumerate()
            .max_by_key(|(_, (_, freq))| *freq)
            .unwrap();
        Some((mf, MatchKind::MostFrequent))
    } else {
        Some((i, kind))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_strips_templates() {
        assert_eq!(
            clean_name("vectorized_elementwise_kernel<4, add_bf16>"),
            "vectorized_elementwise_kernel"
        );
    }

    #[test]
    fn clean_strips_variant_suffixes() {
        assert_eq!(clean_name("gemm_kernel_v2"), "gemm_kernel");
        assert_eq!(clean_name("gemm_kernel_128"), "gemm_kernel");
        assert_eq!(clean_name("gemm_kernel_128_v3"), "gemm_kernel");
        // Non-variant suffixes survive.
        assert_eq!(clean_name("gemm_kernel_tn"), "gemm_kernel_tn");
    }

    #[test]
    fn exact_match() {
        assert_eq!(
            match_kernel("flash_fwd_kernel", "flash_fwd_kernel"),
            MatchKind::Exact
        );
        // Variant suffixes clean away to exact.
        assert_eq!(
            match_kernel("flash_fwd_kernel_v2", "flash_fwd_kernel"),
            MatchKind::Exact
        );
    }

    #[test]
    fn substring_match_both_directions() {
        assert_eq!(
            match_kernel("ampere_gemm_128x64_tn", "ampere_gemm_128x64_tn_splitk"),
            MatchKind::Substring
        );
        assert_eq!(
            match_kernel("ampere_gemm_128x64_tn_splitk", "ampere_gemm_128x64_tn"),
            MatchKind::Substring
        );
    }

    #[test]
    fn unrelated_falls_back() {
        assert_eq!(
            match_kernel("reduce_kernel", "gemm_kernel"),
            MatchKind::MostFrequent
        );
    }

    #[test]
    fn resolve_prefers_exact_over_frequency() {
        let cands = [("gemm_a", 1000usize), ("gemm_target", 1)];
        let (i, kind) = resolve("gemm_target", &cands).unwrap();
        assert_eq!(i, 1);
        assert_eq!(kind, MatchKind::Exact);
    }

    #[test]
    fn resolve_falls_back_to_most_frequent() {
        let cands = [("alpha", 3usize), ("beta", 9), ("gamma", 5)];
        let (i, kind) = resolve("unrelated_name", &cands).unwrap();
        assert_eq!(i, 1);
        assert_eq!(kind, MatchKind::MostFrequent);
    }

    #[test]
    fn resolve_empty() {
        assert!(resolve("x", &[]).is_none());
    }
}
