#![deny(rustdoc::broken_intra_doc_links)]
//! # TaxBreak
//!
//! Production reproduction of *"TaxBreak: Unmasking the Hidden Costs of
//! LLM Inference Through Overhead Decomposition"* (CS.DC 2026).
//!
//! See `README.md` for a quickstart, `DESIGN.md` for the architecture,
//! and `docs/trace_format.md` for the on-disk trace specification.
//!
//! TaxBreak decomposes host-visible LLM-inference orchestration overhead
//! into three mutually exclusive, collectively exhaustive per-kernel
//! components (paper Eq. 1):
//!
//! ```text
//! T_Host = ΔFT + I_lib · ΔCT + ΔKT
//! ```
//!
//! * `ΔFT` — framework translation (Python dispatch + irreducible ATen
//!   dispatch base),
//! * `ΔCT` — CUDA-library front-end translation, charged only to
//!   library-mediated kernels,
//! * `ΔKT` — the launch-path hardware floor (`T_sys_floor`).
//!
//! Summed over a run they give `T_Orchestration` (Eq. 2); together with
//! device-active time they define the **Host-Device Balance Index**
//! (Eq. 3): `HDBI = T_dev / (T_dev + T_orch) ∈ (0, 1)`.
//!
//! ## Crate layout (three-layer architecture, DESIGN.md §4)
//!
//! | module | role |
//! |---|---|
//! | [`util`] | substrates: minijson, stats, RNG, CLI (offline environment) |
//! | [`trace`] | nsys/CUPTI-like event model + IO — the interface every analysis consumes |
//! | [`hardware`] | GPU/CPU specs, H100/H200 platform presets |
//! | [`models`] | dense / MoE architecture descriptors + paper model catalog |
//! | [`kernels`] | kernel-family taxonomy, kernel database, device cost model |
//! | [`lowering`] | model × phase × (BS, SL) → eager kernel launch sequence |
//! | [`host`] | single-threaded host dispatch path (Python/ATen/library/launch) |
//! | [`device`] | GPU stream FIFO (the per-stream primitive) |
//! | [`timeline`] | discrete-event engine: host threads × streams × devices, one clock for sim/whatif/serving |
//! | [`sim`] | host+device co-simulation → traces (single-stream and tensor/expert-parallel scenarios) |
//! | [`taxbreak`] | **the paper's contribution**: two-phase pipeline, Eq. 1-3, baselines, diagnostics |
//! | [`obs`] | live telemetry: metrics registry, streaming windowed decomposition, Prometheus/JSON exposition |
//! | [`serving`] | request router, continuous batcher, reservation-backed paged-KV manager, scheduler, load generator |
//! | [`runtime`] | backend abstraction (simulated / real PJRT), AOT artifact + weights loading, trace instrumentation |
//! | [`whatif`] | counterfactual replay: transform a recorded schedule, re-simulate, quantify each prescription |
//! | [`config`] | typed run configuration |
//! | [`repro`] | regeneration harnesses for every paper table & figure |
//!
//! Python/JAX/Pallas exist only on the `make artifacts` compile path;
//! this crate is self-contained at run time.
//!
//! ## Cargo features
//!
//! * **`real-pjrt`** (off by default) — compiles the real-PJRT code
//!   paths: `runtime::engine` (the PJRT execution engine over AOT
//!   artifacts), `runtime::replay` (the real-mode Phase-2 backend), the
//!   real-mode serving demo, and `ArtifactIndex`-to-literal loading.
//!   The **default build has zero dependency on any xla/PJRT crate**;
//!   every workload runs through the deterministic simulated backend
//!   ([`runtime::SimEngine`]).  In the offline build environment the
//!   feature's `xla` dependency resolves to the in-repo
//!   `vendor/xla-stub` path crate, which build-checks the gated code
//!   without the native `xla_extension` library; swap it for the real
//!   xla-rs crate to actually execute real mode (DESIGN.md §8).

pub mod config;
pub mod device;
pub mod faults;
pub mod hardware;
pub mod host;
pub mod kernels;
pub mod lowering;
pub mod models;
pub mod obs;
pub mod repro;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod taxbreak;
pub mod timeline;
pub mod trace;
pub mod util;
pub mod whatif;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
