//! Hot-path microbenches for the §Perf pass: lowering, simulation,
//! Phase-1/2, decomposition, trace IO, serving scheduler.
//!
//! Run: `cargo bench --bench hot_paths`

use taxbreak::hardware::Platform;
use taxbreak::kernels::KernelDb;
use taxbreak::lowering::{self, LowerOpts, PassKind};
use taxbreak::models;
use taxbreak::serving::synthetic_requests;
use taxbreak::sim::{simulate, simulate_summary, Workload};
use taxbreak::taxbreak::{analyze, decompose, phase2, Phase1, ReplayConfig, SimReplayBackend};
use taxbreak::util::bench::{bench, bench_items, black_box, report};
use taxbreak::util::json::Json;
use taxbreak::util::rng::Rng;

fn main() {
    let platform = Platform::h100();
    let gpt2 = models::gpt2();
    let olmoe = models::olmoe();
    let mut results = Vec::new();

    // --- lowering ------------------------------------------------------
    let olmoe_kernels = {
        let mut rng = Rng::new(1);
        lowering::lower_pass(&olmoe, PassKind::DecodeStep, 4, 1, 2048,
                             &LowerOpts::default(), &mut rng).len()
    };
    results.push(bench_items(
        "lowering::olmoe_decode_step (9.3k kernels)",
        2,
        30,
        olmoe_kernels as f64,
        || {
            let mut rng = Rng::new(1);
            black_box(lowering::lower_pass(
                &olmoe, PassKind::DecodeStep, 4, 1, 2048,
                &LowerOpts::default(), &mut rng,
            ));
        },
    ));

    // --- simulation ------------------------------------------------------
    let wl = Workload::decode(4, 2048, 10);
    let sum = simulate_summary(&olmoe, &platform, &wl, 7);
    results.push(bench_items(
        "sim::summary_olmoe_decode_m10 (93k kernels)",
        1,
        10,
        sum.kernels as f64,
        || {
            black_box(simulate_summary(&olmoe, &platform, &wl, 7));
        },
    ));
    let wl_small = Workload::prefill(1, 512);
    results.push(bench(
        "sim::full_trace_gpt2_prefill (380 kernels)",
        2,
        50,
        || {
            black_box(simulate(&gpt2, &platform, &wl_small, 7));
        },
    ));

    // --- TaxBreak pipeline ----------------------------------------------
    let trace = simulate(&gpt2, &platform, &wl_small, 7);
    results.push(bench_items(
        "phase1::from_trace (gpt2)",
        2,
        50,
        trace.kernel_count() as f64,
        || {
            black_box(Phase1::from_trace(&trace));
        },
    ));
    let p1 = Phase1::from_trace(&trace);
    results.push(bench(
        "phase2::replay (paper W=50/R=150, dedup'd)",
        1,
        10,
        || {
            let mut backend = SimReplayBackend::new(platform.clone(), 3);
            black_box(phase2::run(&p1.db, &mut backend, &ReplayConfig::paper()));
        },
    ));
    let mut backend = SimReplayBackend::new(platform.clone(), 3);
    let p2 = phase2::run(&p1.db, &mut backend, &ReplayConfig::paper());
    results.push(bench(
        "decompose::eq1_eq2 (gpt2 trace)",
        2,
        100,
        || {
            black_box(decompose::decompose(&trace, &p1, &p2));
        },
    ));
    results.push(bench(
        "analyze::end_to_end (gpt2, fast protocol)",
        1,
        10,
        || {
            let mut b = SimReplayBackend::new(platform.clone(), 3);
            black_box(analyze(&trace, &mut b, &ReplayConfig::fast()));
        },
    ));

    // --- trace / json IO -------------------------------------------------
    let json_text = trace.to_json().dump();
    results.push(bench_items(
        "json::parse_trace",
        2,
        20,
        json_text.len() as f64,
        || {
            black_box(Json::parse(&json_text).unwrap());
        },
    ));
    results.push(bench(
        "trace::to_json + dump",
        2,
        20,
        || {
            black_box(trace.to_json().dump());
        },
    ));
    results.push(bench(
        "kernel_db::from_trace",
        2,
        50,
        || {
            black_box(KernelDb::from_trace(&trace));
        },
    ));

    // --- interned event hot paths ----------------------------------------
    // The raw-speed pass: dedup keys are interned-symbol composites, so
    // the per-kernel cache probe is a hash of five Copy fields instead
    // of a formatted String. Both paths are timed — the ratio is the
    // win the interning bought.
    let metas: Vec<_> = trace
        .events
        .iter()
        .filter_map(|e| e.meta.clone())
        .collect();
    results.push(bench_items(
        "intern::dedup_value_key (per-kernel probe)",
        2,
        50,
        metas.len() as f64,
        || {
            for m in &metas {
                black_box(m.dedup());
            }
        },
    ));
    results.push(bench_items(
        "intern::dedup_string_key (legacy render)",
        2,
        50,
        metas.len() as f64,
        || {
            for m in &metas {
                black_box(m.dedup_key());
            }
        },
    ));

    // --- streaming sink chain ---------------------------------------------
    // One event at a time through the binary writer (the loadgen
    // `--capture` path): scratch-buffer reuse keeps this O(1)
    // allocation per event.
    results.push(bench_items(
        "sink::binary_writer_stream (scratch reuse)",
        2,
        30,
        trace.events.len() as f64,
        || {
            use taxbreak::trace::TraceSink;
            let mut w =
                taxbreak::trace::binary::BinaryTraceWriter::new(std::io::sink(), &trace.meta)
                    .unwrap();
            for e in &trace.events {
                TraceSink::event(&mut w, e).unwrap();
            }
            TraceSink::finish(&mut w, trace.meta.wall_us).unwrap();
        },
    ));
    results.push(bench_items(
        "sink::online_decompose_stream (interned maps)",
        2,
        30,
        trace.events.len() as f64,
        || {
            let mut o = taxbreak::obs::OnlineDecomposer::new(0.0);
            for e in &trace.events {
                o.observe(e);
            }
            black_box(o.finalize(platform.clone()));
        },
    ));

    // --- timeline engine ---------------------------------------------------
    // Submit + sync-point polling on a multi-device topology: the
    // ReadyIndex makes every poll O(1) instead of a stream fold.
    results.push(bench_items(
        "timeline::submit_poll_2x2 (ReadyIndex)",
        2,
        30,
        100_000.0,
        || {
            use taxbreak::timeline::{Engine, StreamRef, Topology};
            let mut e = Engine::new(Topology {
                devices: 2,
                streams_per_device: 2,
                host_threads: 1,
            });
            let mut acc = 0.0f64;
            for i in 0..100_000u32 {
                let s = StreamRef { device: i & 1, stream: (i >> 1) & 1 };
                e.submit(s, i as f64, 1.0, 2.5);
                acc += e.sync_point() + e.device_sync_point(i & 1);
            }
            black_box((acc, e.launched()));
        },
    ));

    // --- serving scheduler (mock-speed control loop) -----------------------
    results.push(bench(
        "serving::scheduler_16req (kv+batcher bookkeeping)",
        2,
        30,
        || {
            // In-sim scheduling cost only: measured against the
            // simulator-free mock in unit tests; here we time the
            // bookkeeping around a tiny simulated backend.
            let reqs = synthetic_requests(16, 251, 128, 3);
            black_box(&reqs);
            let mut kv = taxbreak::serving::PagedKvManager::new(64, 16);
            for r in &reqs {
                kv.register(r.id, r.prompt.len()).unwrap();
            }
            for r in &reqs {
                kv.release(r.id).unwrap();
            }
        },
    ));
    report("hot_paths", &results);
}
