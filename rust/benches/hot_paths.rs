//! Hot-path microbenches for the §Perf pass: lowering, simulation,
//! Phase-1/2, decomposition, trace IO, serving scheduler.
//!
//! Run: `cargo bench --bench hot_paths`

use taxbreak::hardware::Platform;
use taxbreak::kernels::KernelDb;
use taxbreak::lowering::{self, LowerOpts, PassKind};
use taxbreak::models;
use taxbreak::serving::synthetic_requests;
use taxbreak::sim::{simulate, simulate_summary, Workload};
use taxbreak::taxbreak::{analyze, decompose, phase2, Phase1, ReplayConfig, SimReplayBackend};
use taxbreak::util::bench::{bench, bench_items, black_box, report};
use taxbreak::util::json::Json;
use taxbreak::util::rng::Rng;

fn main() {
    let platform = Platform::h100();
    let gpt2 = models::gpt2();
    let olmoe = models::olmoe();
    let mut results = Vec::new();

    // --- lowering ------------------------------------------------------
    let olmoe_kernels = {
        let mut rng = Rng::new(1);
        lowering::lower_pass(&olmoe, PassKind::DecodeStep, 4, 1, 2048,
                             &LowerOpts::default(), &mut rng).len()
    };
    results.push(bench_items(
        "lowering::olmoe_decode_step (9.3k kernels)",
        2,
        30,
        olmoe_kernels as f64,
        || {
            let mut rng = Rng::new(1);
            black_box(lowering::lower_pass(
                &olmoe, PassKind::DecodeStep, 4, 1, 2048,
                &LowerOpts::default(), &mut rng,
            ));
        },
    ));

    // --- simulation ------------------------------------------------------
    let wl = Workload::decode(4, 2048, 10);
    let sum = simulate_summary(&olmoe, &platform, &wl, 7);
    results.push(bench_items(
        "sim::summary_olmoe_decode_m10 (93k kernels)",
        1,
        10,
        sum.kernels as f64,
        || {
            black_box(simulate_summary(&olmoe, &platform, &wl, 7));
        },
    ));
    let wl_small = Workload::prefill(1, 512);
    results.push(bench(
        "sim::full_trace_gpt2_prefill (380 kernels)",
        2,
        50,
        || {
            black_box(simulate(&gpt2, &platform, &wl_small, 7));
        },
    ));

    // --- TaxBreak pipeline ----------------------------------------------
    let trace = simulate(&gpt2, &platform, &wl_small, 7);
    results.push(bench_items(
        "phase1::from_trace (gpt2)",
        2,
        50,
        trace.kernel_count() as f64,
        || {
            black_box(Phase1::from_trace(&trace));
        },
    ));
    let p1 = Phase1::from_trace(&trace);
    results.push(bench(
        "phase2::replay (paper W=50/R=150, dedup'd)",
        1,
        10,
        || {
            let mut backend = SimReplayBackend::new(platform.clone(), 3);
            black_box(phase2::run(&p1.db, &mut backend, &ReplayConfig::paper()));
        },
    ));
    let mut backend = SimReplayBackend::new(platform.clone(), 3);
    let p2 = phase2::run(&p1.db, &mut backend, &ReplayConfig::paper());
    results.push(bench(
        "decompose::eq1_eq2 (gpt2 trace)",
        2,
        100,
        || {
            black_box(decompose::decompose(&trace, &p1, &p2));
        },
    ));
    results.push(bench(
        "analyze::end_to_end (gpt2, fast protocol)",
        1,
        10,
        || {
            let mut b = SimReplayBackend::new(platform.clone(), 3);
            black_box(analyze(&trace, &mut b, &ReplayConfig::fast()));
        },
    ));

    // --- trace / json IO -------------------------------------------------
    let json_text = trace.to_json().dump();
    results.push(bench_items(
        "json::parse_trace",
        2,
        20,
        json_text.len() as f64,
        || {
            black_box(Json::parse(&json_text).unwrap());
        },
    ));
    results.push(bench(
        "trace::to_json + dump",
        2,
        20,
        || {
            black_box(trace.to_json().dump());
        },
    ));
    results.push(bench(
        "kernel_db::from_trace",
        2,
        50,
        || {
            black_box(KernelDb::from_trace(&trace));
        },
    ));

    // --- serving scheduler (mock-speed control loop) -----------------------
    results.push(bench(
        "serving::scheduler_16req (kv+batcher bookkeeping)",
        2,
        30,
        || {
            // In-sim scheduling cost only: measured against the
            // simulator-free mock in unit tests; here we time the
            // bookkeeping around a tiny simulated backend.
            let reqs = synthetic_requests(16, 251, 128, 3);
            black_box(&reqs);
            let mut kv = taxbreak::serving::PagedKvManager::new(64, 16);
            for r in &reqs {
                kv.register(r.id, r.prompt.len()).unwrap();
            }
            for r in &reqs {
                kv.release(r.id).unwrap();
            }
        },
    ));
    report("hot_paths", &results);
}
