//! Ablation benches for the design choices DESIGN.md §9 calls out.
//!
//! * `dedup cache` — Phase-2 replay cost with vs without the global
//!   dedup cache (the paper: "saving significant runtime").
//! * `baseline estimator` — median (Eq. 7) vs mean dispatch baseline
//!   under outlier contamination: robustness of ΔCT attribution.
//! * `fused attention` — lowering-level kernel-count/bytes deltas.
//! * `replay protocol` — paper (W=50/R=150) vs fast protocol: accuracy
//!   of the floor estimate vs cost.
//!
//! Run: `cargo bench --bench ablations`

use std::collections::HashMap;

use taxbreak::hardware::Platform;
use taxbreak::lowering::{self, LowerOpts, PassKind};
use taxbreak::models;
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{phase2, Phase1, ReplayConfig, SimReplayBackend};
use taxbreak::util::bench::{bench, black_box, report};
use taxbreak::util::rng::Rng;
use taxbreak::util::stats;

fn main() {
    let platform = Platform::h100();
    let model = models::llama_1b();
    let trace = simulate(&model, &platform, &Workload::prefill(1, 512), 7);
    let p1 = Phase1::from_trace(&trace);
    let mut results = Vec::new();

    // --- dedup cache on/off ---------------------------------------------
    results.push(bench("phase2::cold (every entry profiled)", 1, 5, || {
        let mut backend = SimReplayBackend::new(platform.clone(), 3);
        black_box(phase2::run(&p1.db, &mut backend, &ReplayConfig::paper()));
    }));
    let mut warm_cache = HashMap::new();
    {
        let mut backend = SimReplayBackend::new(platform.clone(), 3);
        phase2::run_with_cache(&p1.db, &mut backend, &ReplayConfig::paper(), &mut warm_cache);
    }
    results.push(bench("phase2::warm (global dedup cache hit)", 1, 5, || {
        let mut backend = SimReplayBackend::new(platform.clone(), 3);
        let mut cache = warm_cache.clone();
        black_box(phase2::run_with_cache(
            &p1.db,
            &mut backend,
            &ReplayConfig::paper(),
            &mut cache,
        ));
    }));

    // --- baseline estimator robustness ------------------------------------
    // Contaminate 5% of framework-native dispatch samples with 10x
    // outliers; compare median vs mean baseline drift.
    let mut rng = Rng::new(9);
    let clean: Vec<f64> = (0..500).map(|_| rng.lognormal_med(10.2, 0.10)).collect();
    let mut dirty = clean.clone();
    let n = dirty.len();
    for i in 0..25 {
        dirty[i * 17 % n] *= 10.0;
    }
    let med_drift = (stats::median(&dirty) - stats::median(&clean)).abs();
    let mean_drift = (stats::mean(&dirty) - stats::mean(&clean)).abs();
    println!(
        "baseline-estimator ablation: 5% 10x outliers -> median drifts \
         {med_drift:.3} us, mean drifts {mean_drift:.3} us \
         ({}x more) — Eq. 7's median is the right choice",
        (mean_drift / med_drift.max(1e-9)).round()
    );
    results.push(bench("stats::median_500", 10, 200, || {
        black_box(stats::median(&dirty));
    }));
    results.push(bench("stats::mean_500", 10, 200, || {
        black_box(stats::mean(&dirty));
    }));

    // --- fused vs eager lowering ------------------------------------------
    let count_bytes = |fused: bool| {
        let mut rng = Rng::new(1);
        let seq = lowering::lower_pass(
            &model,
            PassKind::Prefill,
            8,
            2048,
            2048,
            &LowerOpts {
                fused_attention: fused,
            },
            &mut rng,
        );
        let bytes: f64 = seq.iter().map(|k| k.bytes).sum();
        (seq.len(), bytes)
    };
    let (ek, eb) = count_bytes(false);
    let (fk, fb) = count_bytes(true);
    println!(
        "fused-attention ablation (BS=8/SL=2048): kernels {ek} -> {fk} \
         (-{:.0}%), HBM bytes {:.1} GB -> {:.1} GB (-{:.0}%)",
        100.0 * (1.0 - fk as f64 / ek as f64),
        eb / 1e9,
        fb / 1e9,
        100.0 * (1.0 - fb / eb)
    );

    // --- replay protocol cost/accuracy -------------------------------------
    for (name, cfg) in [
        ("paper (W=50/R=150)", ReplayConfig::paper()),
        ("fast (W=2/R=20)", ReplayConfig::fast()),
    ] {
        let mut backend = SimReplayBackend::new(platform.clone(), 3);
        let p2 = phase2::run(&p1.db, &mut backend, &cfg);
        println!(
            "protocol {name}: floor {:.3} ± (p5 {:.3} / p95 {:.3}) us, base {:.2} us",
            p2.floor.mean, p2.floor.p5, p2.floor.p95, p2.dispatch_base_us
        );
        results.push(bench(&format!("phase2::{name}"), 1, 5, || {
            let mut b = SimReplayBackend::new(platform.clone(), 3);
            black_box(phase2::run(&p1.db, &mut b, &cfg));
        }));
    }

    report("ablations", &results);
}
