//! One end-to-end benchmark per paper table/figure: times the full
//! regeneration pipeline (simulate → two-phase TaxBreak → render) for
//! each artifact on its reduced grid.
//!
//! Run: `cargo bench --bench paper_tables`

use taxbreak::repro::{self, ReproOpts};
use taxbreak::util::bench::{bench, black_box, report};

fn main() {
    let opts = ReproOpts {
        full: false,
        seed: 2026,
    };
    let mut results = Vec::new();
    for id in repro::ALL {
        // Heavy sweeps get fewer iterations; all still run end-to-end.
        let iters = match id {
            "fig5" | "fig6" | "fig8" | "table2" => 1,
            _ => 3,
        };
        results.push(bench(&format!("repro::{id}"), 0, iters, || {
            black_box(repro::run(id, &opts).expect("repro runs"));
        }));
    }
    report("paper_tables (end-to-end regeneration)", &results);
}
