//! Failure-injection and robustness tests: malformed traces, fuzzed
//! JSON, degenerate workloads — the analysis layer must reject or
//! degrade gracefully, never panic.

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::prop_assert;
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{analyze, phase1, ReplayConfig, SimReplayBackend};
use taxbreak::trace::{EventKind, Trace, TraceEvent, TraceMeta, Track};
use taxbreak::util::json::Json;
use taxbreak::util::prop::forall;
use taxbreak::util::rng::Rng;

#[test]
fn validate_rejects_orphaned_kernels() {
    // Kernel events with no runtime-api parent must be flagged.
    let mut t = Trace::new(TraceMeta::default());
    t.push(TraceEvent {
        kind: EventKind::TorchOp,
        name: "torch.mul".into(),
        ts_us: 0.0,
        dur_us: 1.0,
        correlation_id: 1,
        track: Track::Host,
        device: None,
        args: None,
        meta: None,
    });
    t.push(TraceEvent {
        kind: EventKind::Kernel,
        name: "k".into(),
        ts_us: 5.0,
        dur_us: 1.0,
        correlation_id: 1,
        track: Track::Device(0),
        device: None,
        args: None,
        meta: None,
    });
    let err = phase1::validate_trace(&t).unwrap_err().to_string();
    assert!(err.contains("runtime-api"), "{err}");
}

#[test]
fn analysis_survives_kernels_without_meta() {
    // Partial traces (metadata stripped) analyze with those kernels
    // skipped rather than panicking.
    let platform = Platform::h200();
    let mut trace = simulate(&models::gpt2(), &platform, &Workload::prefill(1, 64), 3);
    // Strip meta from every 3rd kernel.
    let mut i = 0;
    for e in trace.events.iter_mut() {
        if e.kind == EventKind::Kernel {
            i += 1;
            if i % 3 == 0 {
                e.meta = None;
            }
        }
    }
    let mut backend = SimReplayBackend::new(platform, 5);
    let a = analyze(&trace, &mut backend, &ReplayConfig::fast());
    assert!(a.decomposition.n_kernels > 0);
    assert!(a.decomposition.n_kernels < trace.kernel_count());
}

#[test]
fn trace_load_rejects_corrupt_files() {
    let dir = std::env::temp_dir().join("taxbreak_failure_injection");
    std::fs::create_dir_all(&dir).unwrap();
    for (name, content) in [
        ("truncated.json", r#"{"meta": {"platform": "h1"#),
        ("wrong_shape.json", r#"{"events": "not-an-array"}"#),
        ("missing_meta.json", r#"{"events": []}"#),
        ("bad_kind.json",
         r#"{"meta":{"platform":"x","model":"y","phase":"z","batch":1,"seq":1,"m_tokens":1,"wall_us":1},
             "events":[{"kind":"quantum","name":"k","ts":0,"dur":1,"corr":1,"track":0}]}"#),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, content).unwrap();
        assert!(Trace::load(&path).is_err(), "{name} should fail to load");
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    forall("json parser total on random bytes", 300, |g| {
        let len = g.usize_in(0, 200);
        let mut bytes = Vec::with_capacity(len);
        for _ in 0..len {
            bytes.push((g.raw_rng().next_u64() & 0xFF) as u8);
        }
        let text = String::from_utf8_lossy(&bytes).to_string();
        // Must return Ok or Err — never panic.
        let _ = Json::parse(&text);
        true
    });
}

#[test]
fn prop_json_roundtrip_on_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_u64() & 1 == 1),
            2 => Json::Num((rng.next_u64() % 100_000) as f64 / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            // Mix ASCII with escapes and multibyte.
                            match rng.below(6) {
                                0 => '"',
                                1 => '\\',
                                2 => '\n',
                                3 => 'é',
                                4 => '😀',
                                _ => (b'a' + rng.below(26) as u8) as char,
                            }
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => {
                let n = rng.below(4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    forall("json dump/parse roundtrip", 200, |g| {
        let v = random_value(g.raw_rng(), 3);
        let text = v.dump();
        let back = Json::parse(&text);
        prop_assert!(g, back.is_ok(), "failed to reparse: {text}");
        prop_assert!(g, back.unwrap() == v, "roundtrip mismatch: {text}");
        let pretty = Json::parse(&v.pretty());
        prop_assert!(g, pretty.map(|p| p == v).unwrap_or(false), "pretty mismatch");
        true
    });
}

#[test]
fn degenerate_workloads_do_not_panic() {
    let p = Platform::h100();
    for model in [models::gpt2(), models::olmoe()] {
        // Tiny and lopsided points.
        for wl in [
            Workload::prefill(1, 1),
            Workload::prefill(16, 1),
            Workload::decode(1, 1, 1),
            Workload::decode(1, 1, 2),
        ] {
            let t = simulate(&model, &p, &wl, 1);
            assert!(t.kernel_count() > 0);
            assert!(t.meta.wall_us > 0.0);
        }
    }
}

#[test]
fn empty_db_phase2_yields_floor_only() {
    let platform = Platform::h100();
    let db = taxbreak::kernels::KernelDb::new();
    let mut backend = SimReplayBackend::new(platform, 2);
    let p2 = taxbreak::taxbreak::phase2::run(&db, &mut backend, &ReplayConfig::fast());
    assert_eq!(p2.kernels.len(), 0);
    assert!(p2.floor.mean > 4.0);
    // Median of an empty set is defined as 0 — ΔCT would gate to 0.
    assert_eq!(p2.dispatch_base_us, 0.0);
}

/// The binary dies with `error: {e:#}` (main.rs), so every diagnostic
/// a bad invocation can produce must render as a single line that
/// names the offending input — never a backtrace or a multi-line
/// chain. Pins the three user-facing failure paths of the audit:
/// a nonexistent trace path, an unwritable output path, and a
/// malformed `--faults` spec.
#[test]
fn cli_failure_diagnostics_are_one_line_and_name_the_input() {
    fn one_line(e: &anyhow::Error) -> String {
        let msg = format!("{e:#}");
        assert!(
            !msg.contains('\n') && !msg.is_empty(),
            "diagnostic must be one non-empty line, got {msg:?}"
        );
        msg
    }

    // `taxbreak analyze --trace MISSING` (and every other loader).
    let missing = std::env::temp_dir().join("taxbreak_no_such_trace.json");
    let msg = one_line(&Trace::load(&missing).unwrap_err());
    assert!(msg.contains("taxbreak_no_such_trace.json"), "must name the path: {msg}");

    // `--report` / `--metrics-out` / `--capture` into a directory
    // that does not exist.
    let unwritable = std::env::temp_dir()
        .join("taxbreak_no_such_dir")
        .join("out.json");
    let trace = simulate(&models::gpt2(), &Platform::h100(), &Workload::prefill(1, 4), 7);
    let msg = one_line(&trace.save(&unwritable).unwrap_err());
    assert!(msg.contains("taxbreak_no_such_dir"), "must name the path: {msg}");

    // Malformed `--faults` specs (rejected eagerly, before any work).
    for spec in [
        "bogus:0:1:2",
        "stall:0:1",
        "stall:0:1:0.5",
        "jitter:0:1:2:sideways",
        "launchfail:0:1:1.5",
        "kv:0:1:1.5",
        "storm:1:0",
        "",
    ] {
        let msg = one_line(&taxbreak::faults::FaultPlan::parse(spec).unwrap_err());
        if !spec.is_empty() {
            let clause = spec.split(':').next().unwrap();
            assert!(msg.contains(clause), "'{spec}' diagnostic must name the clause: {msg}");
        }
    }
}

#[test]
fn cli_args_hostile_inputs() {
    use taxbreak::util::cli::Args;
    // Pathological argv shapes must parse without panicking.
    for argv in [
        vec!["--", "--", "--"],
        vec!["--a=--b", "--=x", "---triple"],
        vec!["--n", "-5"],
        vec![""],
    ] {
        let _ = Args::parse(argv.into_iter().map(|s| s.to_string()));
    }
    let mut a = Args::parse(vec!["--n".to_string(), "99999999999999999999".to_string()]);
    assert!(a.opt_usize("n", 0).is_err(), "overflow must error, not panic");
}
