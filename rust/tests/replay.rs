//! Integration: `serving::replay` — deterministic record/replay.
//!
//! Two locks on the replay contract (DESIGN.md §13):
//!
//! * a **golden corpus** under `tests/golden/replay/` pins one recorded
//!   multi-device, multi-stream serving run byte-for-byte in both
//!   dialects, and pins that replaying it re-records those exact bytes;
//! * a **property suite** checks that arbitrary loadgen configurations
//!   satisfy the record → replay → re-record fixed point in both
//!   dialects, with the replayed KPIs identical to the recorded run's.

use std::path::PathBuf;

use taxbreak::prop_assert;
use taxbreak::serving::loadgen::LenDist;
use taxbreak::serving::{replay, run_sim_loadgen, LoadgenConfig, SchedulerConfig};
use taxbreak::trace::{binary, Trace};
use taxbreak::util::prop::forall;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("replay")
}

/// The corpus workload: multi-device, multi-stream, open-loop — the
/// topology the pre-replay `whatif` path used to reject. Changing any
/// field invalidates the committed corpus; regenerate it with
/// `tests/golden/make_golden.py` (which re-blesses through this test).
fn golden_recording() -> Trace {
    let cfg = LoadgenConfig {
        requests: 8,
        rate_per_s: 1500.0,
        prompt_len: LenDist::Uniform { lo: 8, hi: 24 },
        output_len: LenDist::Uniform { lo: 2, hi: 6 },
        seed: 42,
        devices: 2,
        streams: 2,
        sched: SchedulerConfig { kv_pages: 128, ..SchedulerConfig::default() },
        capture: true,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
    report.runs[0].trace.clone().unwrap()
}

/// One test holds every golden assertion (blessing + byte checks), so
/// parallel test execution never races on the corpus files.
#[test]
fn golden_replay_corpus_is_a_byte_fixed_point_in_both_dialects() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let json_path = dir.join("serve_v3.json");
    let tbt_path = dir.join("serve_v3.tbt");

    let recording = golden_recording();
    let json_bytes = recording.to_json().dump().into_bytes();
    let tbt_bytes = binary::encode(&recording);
    if !json_path.exists() || !tbt_path.exists() {
        std::fs::write(&json_path, &json_bytes).unwrap();
        std::fs::write(&tbt_path, &tbt_bytes).unwrap();
        eprintln!("blessed golden replay corpus into {}", dir.display());
    }

    // The committed corpus matches today's recorder output bit-for-bit
    // (recorder drift must be deliberate: regenerate via make_golden.py).
    assert_eq!(
        std::fs::read(&json_path).unwrap(),
        json_bytes,
        "recorded run drifted from the committed serve_v3.json"
    );
    assert_eq!(
        std::fs::read(&tbt_path).unwrap(),
        tbt_bytes,
        "recorded run drifted from the committed serve_v3.tbt"
    );

    // Replaying the committed corpus re-records those exact bytes —
    // the fixed point, from each dialect's own file.
    let from_json = Trace::load(&json_path).unwrap();
    let out = replay(&from_json).unwrap();
    assert_eq!(
        out.trace.to_json().dump().into_bytes(),
        json_bytes,
        "replay of serve_v3.json is not a JSON-dialect fixed point"
    );
    let from_tbt = Trace::load(&tbt_path).unwrap();
    let out = replay(&from_tbt).unwrap();
    assert_eq!(
        binary::encode(&out.trace),
        tbt_bytes,
        "replay of serve_v3.tbt is not a binary-dialect fixed point"
    );

    // The corpus exercises the previously-rejected topology.
    let devices: std::collections::BTreeSet<u32> =
        from_tbt.events.iter().map(|e| e.device_id()).collect();
    assert_eq!(devices.len(), 2, "corpus must span two replicas");
    assert_eq!(out.run.completed, 8);
}

/// DESIGN.md §14: the telemetry snapshot is a pure function of
/// `(events, wall_us)`, so replaying a recording reproduces not just
/// the bytes but the entire windowed metrics exposition.
#[test]
fn replayed_runs_reproduce_identical_metrics_snapshots() {
    use taxbreak::hardware::Platform;
    let cfg = LoadgenConfig {
        requests: 6,
        rate_per_s: 1200.0,
        seed: 9,
        devices: 2,
        streams: 2,
        sched: SchedulerConfig { kv_pages: 128, ..SchedulerConfig::default() },
        capture: true,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&["olmoe-1b-7b".to_string()], "h200", &cfg).unwrap();
    let recording = report.runs[0].trace.as_ref().unwrap();
    let out = replay(recording).unwrap();

    let platform = Platform::by_name("h200").unwrap();
    let window_us = recording.e2e_us() / 6.0;
    let (rep_rec, reg_rec) =
        taxbreak::obs::snapshot_of_trace(recording, platform.clone(), window_us);
    let (rep_out, reg_out) = taxbreak::obs::snapshot_of_trace(&out.trace, platform, window_us);
    assert_eq!(
        reg_rec.prometheus_text(),
        reg_out.prometheus_text(),
        "the Prometheus exposition must be a replay fixed point"
    );
    assert_eq!(reg_rec.to_json().dump(), reg_out.to_json().dump());
    assert!(rep_rec.totals.n_kernels > 0);
    assert_eq!(rep_rec.totals.n_kernels, rep_out.totals.n_kernels);
    assert!(rep_rec.windows.len() > 1, "a fractional window splits the run");
}

#[test]
fn prop_arbitrary_loadgen_configs_satisfy_the_replay_fixed_point() {
    forall("record → replay → re-record is byte-equal", 10, |g| {
        let devices = g.usize_in(1, 3);
        let cfg = LoadgenConfig {
            // >= one request per replica keeps every replica's script
            // non-empty, so the per-device KPI partition compares 1:1.
            requests: g.usize_in(devices, 8),
            rate_per_s: *g.choice(&[0.0, 600.0, 2500.0]),
            prompt_len: LenDist::Uniform { lo: g.usize_in(1, 8), hi: g.usize_in(8, 32) },
            output_len: LenDist::Uniform { lo: 1, hi: g.usize_in(1, 6) },
            seed: g.u64(),
            devices,
            streams: g.usize_in(1, 2),
            sched: SchedulerConfig {
                max_batch: g.usize_in(1, 8),
                kv_pages: 64 * devices,
                ..SchedulerConfig::default()
            },
            capture: true,
            ..LoadgenConfig::default()
        };
        let model = g.choice(&["gpt2", "olmoe-1b-7b"]).to_string();
        let platform = g.choice(&["h100", "h200"]).to_string();

        let report = run_sim_loadgen(&[model], &platform, &cfg).unwrap();
        let orig = &report.runs[0];
        let recording = orig.trace.as_ref().unwrap();
        let out = replay(recording).unwrap();

        prop_assert!(
            g,
            out.trace.to_json().dump() == recording.to_json().dump(),
            "JSON dialect fixed point violated"
        );
        prop_assert!(
            g,
            binary::encode(&out.trace) == binary::encode(recording),
            "binary dialect fixed point violated"
        );
        prop_assert!(
            g,
            (out.run.completed, out.run.iterations, out.run.tokens_generated)
                == (orig.completed, orig.iterations, orig.tokens_generated),
            "replayed KPIs diverged: {:?} vs {:?}",
            (out.run.completed, out.run.iterations, out.run.tokens_generated),
            (orig.completed, orig.iterations, orig.tokens_generated)
        );
        prop_assert!(
            g,
            out.run.phases == orig.phases,
            "replayed decomposition diverged"
        );
        prop_assert!(
            g,
            (out.run.wall_us - orig.wall_us).abs() < 1e-12,
            "replayed wall diverged: {} vs {}",
            out.run.wall_us,
            orig.wall_us
        );
        true
    });
}
