//! Conformance tests for the on-disk trace format against its spec,
//! `docs/trace_format.md`.
//!
//! Two guarantees:
//! 1. save → load → save is **byte-stable** (the format is canonical:
//!    insertion-ordered keys, shortest-roundtrip numbers);
//! 2. the emitted field names and event-kind tags are exactly the ones
//!    the spec documents — adding/renaming a field or an `EventKind`
//!    variant without updating `docs/trace_format.md` fails here
//!    (spec drift = test failure).

use std::path::PathBuf;

use taxbreak::trace::chrome::to_chrome_json;
use taxbreak::trace::{EventKind, KernelMeta, ReplayArgs, Trace, TraceEvent, TraceMeta, Track};
use taxbreak::util::json::Json;

/// Field names documented in docs/trace_format.md §3 (TraceMeta).
const META_FIELDS: [&str; 7] = [
    "platform", "model", "phase", "batch", "seq", "m_tokens", "wall_us",
];
/// Field names documented in docs/trace_format.md §4 (TraceEvent).
/// `device`, `args` and `meta` are optional; when present they keep
/// this order.
const EVENT_FIELDS: [&str; 9] = [
    "kind", "name", "ts", "dur", "corr", "track", "device", "args", "meta",
];
/// Field names documented in docs/trace_format.md §5 (KernelMeta).
const KERNEL_META_FIELDS: [&str; 9] = [
    "kernel_name", "family", "aten_op", "shapes_key", "grid", "block", "lib", "flops", "bytes",
];
/// Field names documented in docs/trace_format.md §7 (chrome export).
const CHROME_FIELDS: [&str; 8] = ["name", "cat", "ph", "ts", "dur", "pid", "tid", "args"];

fn spec_text() -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("trace_format.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading spec {}: {e}", path.display()))
}

fn keys(v: &Json) -> Vec<&str> {
    match v {
        Json::Obj(entries) => entries.iter().map(|(k, _)| k.as_str()).collect(),
        other => panic!("expected an object, got {other:?}"),
    }
}

/// A trace exercising every event kind, both tracks, kernels with and
/// without metadata, and fractional/integral timestamps.
fn sample_trace() -> Trace {
    let mut t = Trace::new(TraceMeta {
        platform: "h100".into(),
        model: "llama-3.2-1b".into(),
        phase: "decode".into(),
        batch: 4,
        seq: 2048,
        m_tokens: 10,
        wall_us: 1234.5,
    });
    let host = |kind, corr, ts: f64, dur: f64, name: &str| TraceEvent {
        kind,
        name: name.to_string(),
        ts_us: ts,
        dur_us: dur,
        correlation_id: corr,
        track: Track::Host,
        device: None,
        args: None,
        meta: None,
    };
    t.push(host(EventKind::TorchOp, 1, 0.0, 2.5, "torch.mm"));
    t.push(host(EventKind::AtenOp, 1, 1.0, 1.5, "aten::mm"));
    t.push(host(EventKind::RuntimeApi, 1, 2.0, 0.5, "cudaLaunchKernel"));
    t.push(TraceEvent {
        kind: EventKind::Kernel,
        name: "ampere_bf16_s16816gemm_q_64x2048x2048_tn".into(),
        ts_us: 7.25,
        dur_us: 3.0,
        correlation_id: 1,
        track: Track::Device(0),
        device: None,
        args: None,
        meta: Some(KernelMeta {
            kernel_name: "ampere_bf16_s16816gemm_q_64x2048x2048_tn".into(),
            family: "gemm_cublas".into(),
            aten_op: "aten::mm".into(),
            shapes_key: "bf16[1,64,2048]x[2048,2048]".into(),
            grid: [1, 16, 1],
            block: [256, 1, 1],
            lib_mediated: true,
            flops: 2.0 * 64.0 * 2048.0 * 2048.0,
            bytes: 17_039_360.0,
        }),
    });
    t.push(host(EventKind::Nvtx, 2, 20.0, 8.0, "replay:scope"));
    // A metadata-less kernel on a second stream.
    t.push(TraceEvent {
        kind: EventKind::Kernel,
        name: "memset_kernel".into(),
        ts_us: 30.0,
        dur_us: 1.0,
        correlation_id: 2,
        track: Track::Device(3),
        device: None,
        args: None,
        meta: None,
    });
    // A kernel stamped onto a second *device* (spec v2 optional field):
    // stream 0 of device 1.
    t.push(TraceEvent {
        kind: EventKind::Kernel,
        name: "nccl_all_reduce_ring".into(),
        ts_us: 31.0,
        dur_us: 2.0,
        correlation_id: 3,
        track: Track::Device(0),
        device: Some(1),
        args: None,
        meta: None,
    });
    t
}

/// A trace exercising the four spec-v3 recording kinds and their
/// `args` payloads (separate from [`sample_trace`] so the chrome /
/// track-index tests keep their fixed shapes).
fn v3_sample_trace() -> Trace {
    let mut t = Trace::new(TraceMeta {
        platform: "h200".into(),
        model: "gpt2".into(),
        phase: "serve".into(),
        batch: 0,
        seq: 0,
        m_tokens: 0,
        wall_us: 99.5,
    });
    let v3 = |kind, ts: f64, dur: f64, name: &str, device, args| TraceEvent {
        kind,
        name: name.to_string(),
        ts_us: ts,
        dur_us: dur,
        correlation_id: 0,
        track: Track::Host,
        device,
        args,
        meta: None,
    };
    t.push(v3(
        EventKind::Arrival,
        0.0,
        0.0,
        "arrival",
        None,
        Some(ReplayArgs::Arrival { req: 0, plen: 32, max_new: 4, model: "gpt2".into() }),
    ));
    t.push(v3(
        EventKind::RngDraw,
        1.0,
        0.0,
        "prep::prefill_b1",
        None,
        Some(ReplayArgs::RngDraw { site: "prep::prefill_b1".into(), value: 30.75 }),
    ));
    t.push(v3(
        EventKind::ClockJump,
        2.0,
        5.5,
        "clock_jump",
        Some(1),
        None,
    ));
    t.push(v3(
        EventKind::SchedDecision,
        7.5,
        0.0,
        "sched_decision",
        Some(1),
        Some(ReplayArgs::SchedDecision {
            step: 1,
            admitted: vec![vec![0, 2], vec![1]],
            preempted: vec![3],
            shed: vec![],
            batch: 4,
        }),
    ));
    t
}

/// A trace exercising the spec-v4 extensions: a `fault` event and a
/// scheduler decision with a non-empty `shed` list (kept separate from
/// [`v3_sample_trace`] so the v3 byte-identity guarantee — empty shed
/// serializes to exactly the v3 shape — stays pinned there).
fn v4_sample_trace() -> Trace {
    let mut t = Trace::new(TraceMeta {
        platform: "h200".into(),
        model: "gpt2".into(),
        phase: "serve".into(),
        batch: 0,
        seq: 0,
        m_tokens: 0,
        wall_us: 420.0,
    });
    t.push(TraceEvent {
        kind: EventKind::Fault,
        name: "fault".into(),
        ts_us: 100.0,
        dur_us: 250.5,
        correlation_id: 0,
        track: Track::Host,
        device: None,
        args: Some(ReplayArgs::Fault {
            kind: "device_stall".into(),
            target: "stream:1".into(),
            onset_us: 100.0,
            dur_us: 250.5,
            magnitude: 4.0,
        }),
        meta: None,
    });
    t.push(TraceEvent {
        kind: EventKind::SchedDecision,
        name: "sched_decision".into(),
        ts_us: 150.0,
        dur_us: 0.0,
        correlation_id: 0,
        track: Track::Host,
        device: Some(2),
        args: Some(ReplayArgs::SchedDecision {
            step: 3,
            admitted: vec![vec![7]],
            preempted: vec![],
            shed: vec![5, 9],
            batch: 2,
        }),
        meta: None,
    });
    t
}

#[test]
fn save_load_save_is_byte_stable() {
    let dir = std::env::temp_dir().join("taxbreak_trace_format_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("first.json");
    let p2 = dir.join("second.json");

    let t = sample_trace();
    t.save(&p1).unwrap();
    let loaded = Trace::load(&p1).unwrap();
    assert_eq!(loaded, t, "load must reconstruct the trace exactly");
    loaded.save(&p2).unwrap();

    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    assert_eq!(b1, b2, "save -> load -> save must be byte-identical");
}

#[test]
fn emitted_fields_match_documented_names_exactly() {
    let j = sample_trace().to_json();
    assert_eq!(keys(&j), vec!["meta", "events"]);
    assert_eq!(keys(j.req("meta").unwrap()), META_FIELDS.to_vec());

    let events = j.arr_of("events").unwrap();
    let mut saw_device = false;
    for ev in events {
        let ks = keys(ev);
        // `device`, `args` and `meta` are optional; present fields must
        // match the documented names in the documented order.
        let expected: Vec<&str> = EVENT_FIELDS
            .iter()
            .copied()
            .filter(|f| !matches!(*f, "device" | "args" | "meta") || ks.contains(f))
            .collect();
        assert_eq!(ks, expected, "event field names/order drifted");
        saw_device |= ks.contains(&"device");
        if let Some(meta) = ev.get("meta") {
            assert_eq!(keys(meta), KERNEL_META_FIELDS.to_vec());
        }
    }
    assert!(saw_device, "sample trace must exercise the device field");
}

#[test]
fn spec_documents_every_field_and_event_kind() {
    let spec = spec_text();
    for field in META_FIELDS
        .iter()
        .chain(EVENT_FIELDS.iter())
        .chain(KERNEL_META_FIELDS.iter())
        .chain(CHROME_FIELDS.iter())
    {
        assert!(
            spec.contains(&format!("`{field}`")),
            "docs/trace_format.md does not document field `{field}`"
        );
    }
    for kind in EventKind::ALL {
        assert!(
            spec.contains(&format!("`{}`", kind.as_str())),
            "docs/trace_format.md does not document event kind `{}`",
            kind.as_str()
        );
    }
}

#[test]
fn track_encoding_matches_spec() {
    // Spec §4: host == -1, device stream s == s (>= 0); `device` is
    // present only when stamped.
    let j = sample_trace().to_json();
    let events = j.arr_of("events").unwrap();
    assert_eq!(events[0].f64_of("track").unwrap(), -1.0);
    assert_eq!(events[3].f64_of("track").unwrap(), 0.0);
    assert_eq!(events[5].f64_of("track").unwrap(), 3.0);
    assert!(events[5].get("device").is_none());
    assert_eq!(events[6].f64_of("track").unwrap(), 0.0);
    assert_eq!(events[6].usize_of("device").unwrap(), 1);
}

#[test]
fn numbers_follow_canonical_form() {
    // Spec §6: integral values print without a fractional part;
    // non-integral values use shortest-roundtrip formatting.
    let text = sample_trace().to_json().dump();
    assert!(text.contains("\"ts\":7.25"));
    assert!(text.contains("\"dur\":3,"), "integral duration must print as 3");
    assert!(text.contains("\"batch\":4"));
    assert!(text.contains("\"wall_us\":1234.5"));
}

#[test]
fn chrome_export_fields_match_spec() {
    let t = sample_trace();
    let chrome = to_chrome_json(&t);
    let arr = chrome.as_arr().unwrap();
    // §7: one leading process-name metadata event, one thread_name
    // metadata event per distinct tid (first-appearance order), then
    // one complete event per trace event, in order. The sample's tids:
    // 0 (host), 100 (dev0/s0), 103 (dev0/s3), 1100 (dev1/s0).
    assert_eq!(arr.len(), 1 + 4 + t.events.len());
    let meta = &arr[0];
    assert_eq!(
        keys(meta),
        vec!["name", "ph", "pid", "tid", "args"],
        "metadata event field order"
    );
    assert_eq!(meta.str_of("name").unwrap(), "process_name");
    assert_eq!(meta.str_of("ph").unwrap(), "M");
    assert_eq!(
        meta.req("args").unwrap().str_of("name").unwrap(),
        format!("{} {} @ {}", t.meta.model, t.meta.phase, t.meta.platform)
    );
    let expected_threads = [
        (0.0, "host (dev 0)"),
        (100.0, "dev 0 stream 0"),
        (103.0, "dev 0 stream 3"),
        (1100.0, "dev 1 stream 0"),
    ];
    for (tn, (tid, label)) in arr[1..5].iter().zip(expected_threads) {
        assert_eq!(keys(tn), vec!["name", "ph", "pid", "tid", "args"]);
        assert_eq!(tn.str_of("name").unwrap(), "thread_name");
        assert_eq!(tn.str_of("ph").unwrap(), "M");
        assert_eq!(tn.f64_of("tid").unwrap(), tid);
        assert_eq!(tn.req("args").unwrap().str_of("name").unwrap(), label);
    }
    for ev in &arr[5..] {
        assert_eq!(keys(ev), CHROME_FIELDS.to_vec());
        assert_eq!(ev.str_of("ph").unwrap(), "X");
    }
    // Host tid 1000*d; device stream s -> tid 1000*d + 100 + s.
    assert_eq!(arr[5].f64_of("tid").unwrap(), 0.0);
    assert_eq!(arr[8].f64_of("tid").unwrap(), 100.0);
    assert_eq!(arr[10].f64_of("tid").unwrap(), 103.0);
    assert_eq!(arr[11].f64_of("tid").unwrap(), 1100.0);
}

#[test]
fn chrome_counter_events_match_spec() {
    use taxbreak::trace::chrome::{to_chrome_json_with_counters, CounterSeries};
    let t = sample_trace();
    let counters = [
        CounterSeries { name: "hdbi".into(), points: vec![(0.0, 0.25), (500.0, 0.75)] },
        CounterSeries { name: "kv_occupancy".into(), points: vec![(0.0, 0.5)] },
    ];
    let chrome = to_chrome_json_with_counters(&t, &counters);
    let arr = chrome.as_arr().unwrap();
    // §7.1: counter ("C") events append after the complete events, one
    // per point, series in caller order.
    let base = 1 + 4 + t.events.len();
    assert_eq!(arr.len(), base + 3);
    let expected = [("hdbi", 0.0, 0.25), ("hdbi", 500.0, 0.75), ("kv_occupancy", 0.0, 0.5)];
    for (c, (name, ts, value)) in arr[base..].iter().zip(expected) {
        assert_eq!(keys(c), vec!["name", "ph", "ts", "pid", "tid", "args"]);
        assert_eq!(c.str_of("name").unwrap(), name);
        assert_eq!(c.str_of("ph").unwrap(), "C");
        assert_eq!(c.f64_of("ts").unwrap(), ts);
        assert_eq!(c.f64_of("pid").unwrap(), 1.0);
        assert_eq!(c.f64_of("tid").unwrap(), 0.0);
        let args = c.req("args").unwrap();
        assert_eq!(keys(args), vec![name], "args holds exactly the series key");
        assert_eq!(args.f64_of(name).unwrap(), value);
    }
    // An empty counter list reduces to the plain export, byte for byte.
    assert_eq!(
        to_chrome_json_with_counters(&t, &[]).dump(),
        to_chrome_json(&t).dump()
    );
}

#[test]
fn event_kind_tags_roundtrip_the_documented_set() {
    let documented = [
        "torch_op",
        "aten_op",
        "runtime_api",
        "kernel",
        "nvtx",
        "arrival",
        "rng_draw",
        "sched_decision",
        "clock_jump",
        "fault",
    ];
    assert_eq!(EventKind::ALL.len(), documented.len());
    for (kind, tag) in EventKind::ALL.iter().zip(documented) {
        assert_eq!(kind.as_str(), tag);
        assert_eq!(EventKind::parse(tag).unwrap(), *kind);
    }
}

#[test]
fn v3_args_payloads_match_documented_keys_exactly() {
    // Spec §4.2: the args object is untagged (the event kind selects
    // the shape) and its keys are pinned, in order.
    let j = v3_sample_trace().to_json();
    let events = j.arr_of("events").unwrap();
    assert_eq!(
        keys(&events[0]),
        vec!["kind", "name", "ts", "dur", "corr", "track", "args"]
    );
    assert_eq!(
        keys(events[0].req("args").unwrap()),
        vec!["req", "plen", "max_new", "model"]
    );
    assert_eq!(keys(events[1].req("args").unwrap()), vec!["site", "value"]);
    // ClockJump carries no args; a stamped device still precedes it.
    assert_eq!(
        keys(&events[2]),
        vec!["kind", "name", "ts", "dur", "corr", "track", "device"]
    );
    assert_eq!(
        keys(&events[3]),
        vec!["kind", "name", "ts", "dur", "corr", "track", "device", "args"]
    );
    assert_eq!(
        keys(events[3].req("args").unwrap()),
        vec!["step", "admitted", "preempted", "batch"]
    );
    // Group boundaries survive: admitted is a list of lists.
    let admitted = events[3].req("args").unwrap().arr_of("admitted").unwrap();
    assert_eq!(admitted.len(), 2);
    assert_eq!(admitted[0].as_arr().unwrap().len(), 2);
}

#[test]
fn v3_trace_is_byte_stable_and_replay_kinds_carry_corr_zero() {
    let t = v3_sample_trace();
    let text = t.to_json().dump();
    let back = Trace::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, t, "v3 JSON round trip must reconstruct the trace");
    assert_eq!(back.to_json().dump(), text, "v3 JSON must be byte-stable");
    assert!(t.events.iter().all(|e| e.correlation_id == 0));
    // A has-args kind without its payload is a parse error, not a
    // silently defaulted event.
    let mut stripped = Json::parse(&text).unwrap();
    if let Json::Obj(entries) = &mut stripped {
        let events = entries.iter_mut().find(|(k, _)| k == "events").unwrap();
        if let Json::Arr(evs) = &mut events.1 {
            if let Json::Obj(fields) = &mut evs[0] {
                fields.retain(|(k, _)| k != "args");
            }
        }
    }
    let err = Trace::from_json(&stripped).unwrap_err().to_string();
    assert!(err.contains("lacks its args payload"), "{err}");
}

#[test]
fn v4_args_payloads_match_documented_keys_exactly() {
    // Spec §4.3: `fault` args keys are pinned, in order; a non-empty
    // `shed` list slots between `preempted` and `batch`.
    let j = v4_sample_trace().to_json();
    let events = j.arr_of("events").unwrap();
    assert_eq!(
        keys(events[0].req("args").unwrap()),
        vec!["kind", "target", "onset_us", "dur_us", "magnitude"]
    );
    assert_eq!(
        keys(events[1].req("args").unwrap()),
        vec!["step", "admitted", "preempted", "shed", "batch"]
    );
    let shed = events[1].req("args").unwrap().arr_of("shed").unwrap();
    assert_eq!(shed.len(), 2);
}

#[test]
fn v4_trace_is_byte_stable_and_empty_shed_stays_v3_shaped() {
    let t = v4_sample_trace();
    let text = t.to_json().dump();
    let back = Trace::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, t, "v4 JSON round trip must reconstruct the trace");
    assert_eq!(back.to_json().dump(), text, "v4 JSON must be byte-stable");
    assert!(t.events.iter().all(|e| e.correlation_id == 0));
    // The v3 sample (empty shed everywhere) must not leak a `shed` key:
    // pre-fault captures re-saved under v4 code stay byte-identical.
    assert!(!v3_sample_trace().to_json().dump().contains("\"shed\""));
}
