//! Integration tests: the full simulate → trace → two-phase TaxBreak
//! pipeline across models, platforms and phases, checking the paper's
//! cross-cutting claims end to end.

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::sim::{simulate, simulate_summary, Workload};
use taxbreak::taxbreak::{
    analyze, phase1::validate_trace, Analysis, OptimizationTarget, ReplayConfig,
    SimReplayBackend,
};
use taxbreak::trace::Trace;

fn analyze_wl(model: &models::ModelSpec, platform: &Platform, wl: &Workload) -> Analysis {
    let trace = simulate(model, platform, wl, 1234);
    let mut backend = SimReplayBackend::new(platform.clone(), 99);
    analyze(&trace, &mut backend, &ReplayConfig::fast())
}

#[test]
fn every_catalog_model_analyzes_on_every_platform() {
    for model in models::catalog() {
        for platform in Platform::all() {
            let a = analyze_wl(&model, &platform, &Workload::prefill(1, 128));
            assert!(a.decomposition.n_kernels > 100, "{}", model.name);
            assert!(a.decomposition.hdbi() > 0.0 && a.decomposition.hdbi() < 1.0);
            assert!((a.phase2.floor.mean - platform.gpu.t_sys_floor_us).abs() < 0.3);
        }
    }
}

#[test]
fn traces_are_structurally_valid() {
    for model in models::catalog() {
        let t = simulate(&model, &Platform::h100(), &Workload::decode(2, 256, 3), 5);
        validate_trace(&t).unwrap();
    }
}

#[test]
fn trace_roundtrip_preserves_analysis() {
    let platform = Platform::h200();
    let model = models::gpt2();
    let trace = simulate(&model, &platform, &Workload::prefill(2, 256), 8);

    let dir = std::env::temp_dir().join("taxbreak_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.json");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded, trace);

    let a1 = {
        let mut b = SimReplayBackend::new(platform.clone(), 7);
        analyze(&trace, &mut b, &ReplayConfig::fast())
    };
    let a2 = {
        let mut b = SimReplayBackend::new(platform.clone(), 7);
        analyze(&loaded, &mut b, &ReplayConfig::fast())
    };
    assert_eq!(a1.decomposition.n_kernels, a2.decomposition.n_kernels);
    assert!((a1.decomposition.orchestration_us() - a2.decomposition.orchestration_us()).abs() < 1e-6);
}

#[test]
fn takeaway1_dense_shifts_moe_stays_host_bound() {
    // Key Takeaway #1: dense moves from host-bound to compute-bound as
    // workload grows; MoE decode does not.
    let p = Platform::h100();
    let dense_small = analyze_wl(&models::llama_1b(), &p, &Workload::prefill(1, 512));
    let dense_big = analyze_wl(&models::llama_1b(), &p, &Workload::prefill(8, 4096));
    assert!(dense_small.decomposition.hdbi() < 0.5);
    assert!(dense_big.decomposition.hdbi() > 0.85, "{}", dense_big.decomposition.hdbi());

    let moe_small = analyze_wl(&models::olmoe(), &p, &Workload::decode(1, 512, 3));
    let moe_big = analyze_wl(&models::olmoe(), &p, &Workload::decode(8, 2048, 3));
    assert!(moe_small.decomposition.hdbi() < 0.35);
    assert!(
        moe_big.decomposition.hdbi() < 0.5,
        "MoE decode must stay host-bound: {}",
        moe_big.decomposition.hdbi()
    );
}

#[test]
fn takeaway2_moe_kernel_inflation() {
    // 8-11x more kernels per output token (Table II).
    let p = Platform::h100();
    let m = 10;
    let dense = simulate_summary(&models::llama_1b(), &p, &Workload::decode(4, 2048, m), 3);
    let moe = simulate_summary(&models::olmoe(), &p, &Workload::decode(4, 2048, m), 3);
    let ratio = moe.kernels as f64 / dense.kernels as f64;
    assert!((8.0..14.0).contains(&ratio), "ratio {ratio}");
}

#[test]
fn takeaway5_faster_cpu_wins_for_host_bound() {
    // H200 (faster CPU, slower GPU) beats H100 end-to-end on MoE decode.
    let wl = Workload::decode(1, 512, 5);
    let moe = models::qwen_moe();
    let h100 = simulate_summary(&moe, &Platform::h100(), &wl, 3);
    let h200 = simulate_summary(&moe, &Platform::h200(), &wl, 3);
    assert!(h200.wall_us < h100.wall_us);

    // ...but not (much) for a device-bound dense prefill.
    let dense_wl = Workload::prefill(8, 4096);
    let d100 = simulate_summary(&models::llama_1b(), &Platform::h100(), &dense_wl, 3);
    let d200 = simulate_summary(&models::llama_1b(), &Platform::h200(), &dense_wl, 3);
    let moe_gain = 1.0 - h200.wall_us / h100.wall_us;
    let dense_gain = 1.0 - d200.wall_us / d100.wall_us;
    assert!(
        moe_gain > 2.0 * dense_gain.max(0.0),
        "moe gain {moe_gain} should dwarf dense gain {dense_gain}"
    );
}

#[test]
fn diagnosis_prescribes_correctly_per_regime() {
    let p = Platform::h100();
    // Device-bound big dense prefill -> device work.
    let a = analyze_wl(&models::llama_3b(), &p, &Workload::prefill(16, 4096));
    assert_eq!(a.diagnosis.target, OptimizationTarget::DeviceWork);
    // Host-bound MoE decode -> software stack or fusion, never device.
    let a = analyze_wl(&models::olmoe(), &p, &Workload::decode(1, 512, 2));
    assert_ne!(a.diagnosis.target, OptimizationTarget::DeviceWork);
}

#[test]
fn decode_totals_scale_with_window() {
    // T_Orchestration of the m=10 window ≈ 10x the prefill value
    // (§V-C: per-step orchestration is nearly identical).
    let p = Platform::h200();
    let model = models::llama_1b();
    let a1 = analyze_wl(&model, &p, &Workload::prefill(1, 512));
    let a10 = analyze_wl(&model, &p, &Workload::decode(1, 512, 10));
    let ratio = a10.decomposition.orchestration_us() / a1.decomposition.orchestration_us();
    assert!((8.5..11.5).contains(&ratio), "ratio {ratio}");
}

#[test]
fn hdbi_and_idle_fraction_are_consistent() {
    // idle fraction >= 1 - HDBI-ish relation: e2e >= dev + orch is not
    // guaranteed (overlap), but idle must always exceed zero when
    // HDBI < 1 and both must match the trace's own accounting.
    let p = Platform::h200();
    for model in [models::gpt2(), models::olmoe()] {
        let trace = simulate(&model, &p, &Workload::prefill(1, 256), 4);
        let mut b = SimReplayBackend::new(p.clone(), 5);
        let a = analyze(&trace, &mut b, &ReplayConfig::fast());
        let d = &a.decomposition;
        assert!((d.device_active_us - trace.device_active_us()).abs() < 1e-6);
        assert!((d.e2e_us - trace.e2e_us()).abs() < 1e-6);
        assert!(d.idle_fraction() > 0.0 && d.idle_fraction() < 1.0);
    }
}

#[test]
fn fused_attention_strictly_reduces_bytes_and_kernels() {
    let p = Platform::h200();
    let model = models::llama_1b();
    for (bs, sl) in [(1, 512), (4, 1024), (8, 2048)] {
        let eager = simulate_summary(&model, &p, &Workload::prefill(bs, sl), 2);
        let fused = simulate_summary(
            &model,
            &p,
            &Workload::prefill(bs, sl).with_fused_attention(true),
            2,
        );
        assert!(fused.kernels < eager.kernels);
        assert!(fused.device_active_us < eager.device_active_us);
        assert!(fused.wall_us < eager.wall_us);
    }
}

#[test]
fn prescriptions_win_in_their_regime() {
    // The diagnostic's prescriptions (§III), validated as what-ifs:
    // host-bound MoE decode must benefit most from torch.compile /
    // CUDA graphs; device-bound dense prefill must NOT.
    use taxbreak::sim::Mitigation;
    let p = Platform::h100();
    let moe = models::olmoe();
    let wl = Workload::decode(1, 512, 10);
    let base = simulate_summary(&moe, &p, &wl, 7).wall_us;
    let compiled = simulate_summary(
        &moe, &p, &wl.clone().with_mitigation(Mitigation::TorchCompile), 7,
    )
    .wall_us;
    let graphs = simulate_summary(
        &moe, &p, &wl.clone().with_mitigation(Mitigation::CudaGraphs), 7,
    )
    .wall_us;
    assert!(compiled < 0.6 * base, "compile: {compiled} vs {base}");
    assert!(graphs < 0.5 * base, "graphs: {graphs} vs {base}");

    // Device-bound dense prefill (already using fused attention so
    // compilation can't remove device work): host-side mitigations
    // barely move e2e.
    let dense = models::llama_1b();
    let dwl = Workload::prefill(8, 4096).with_fused_attention(true);
    let dbase = simulate_summary(&dense, &p, &dwl, 7).wall_us;
    let dcomp = simulate_summary(
        &dense, &p, &dwl.clone().with_mitigation(Mitigation::TorchCompile), 7,
    )
    .wall_us;
    assert!(
        (dbase - dcomp) / dbase < 0.15,
        "device-bound should gain little: {dbase} -> {dcomp}"
    );
}

#[test]
fn cuda_graphs_amortize_the_launch_path() {
    // With graphs, decode steps issue one host launch instead of ~9.3k;
    // TKLQT collapses while device work is unchanged (modulo jitter).
    use taxbreak::sim::Mitigation;
    let p = Platform::h100();
    let moe = models::olmoe();
    let wl = Workload::decode(1, 512, 5);
    let base = simulate_summary(&moe, &p, &wl, 3);
    let graphs = simulate_summary(
        &moe, &p, &wl.clone().with_mitigation(Mitigation::CudaGraphs), 3,
    );
    assert_eq!(base.kernels, graphs.kernels, "graphs replay the same kernels");
    assert!(graphs.host_busy_us < 0.4 * base.host_busy_us);
    let dev_ratio = graphs.device_active_us / base.device_active_us;
    assert!((0.9..1.1).contains(&dev_ratio), "device work unchanged: {dev_ratio}");
}

#[test]
fn ci_stability_of_orchestration() {
    // Paper §IV: "the 95% CI of T_Orchestration remains below 0.34 ms
    // across all configurations" — verify measurement stability over
    // repeated runs of the GPT-2 point.
    use taxbreak::util::stats;
    let p = Platform::h200();
    let model = models::gpt2();
    let runs: Vec<f64> = (0..30)
        .map(|r| {
            let trace = simulate(&model, &p, &Workload::prefill(1, 512), 5000 + r);
            let mut b = SimReplayBackend::new(p.clone(), 60 + r);
            let a = analyze(&trace, &mut b, &ReplayConfig::fast());
            a.decomposition.orchestration_us()
        })
        .collect();
    let ci = stats::ci95_half_width(&runs);
    assert!(ci < 340.0, "95% CI of T_Orchestration {ci} us (paper: < 340 us)");
}
