#!/usr/bin/env python3
"""Regenerate the golden trace corpus (v1_min / v2_multi / v3_replay /
v4_fault, both dialects) and re-bless the recorded replay corpus.

Byte-exact replica of the Rust canonical JSON dumper
(`util::json::Json::dump`, spec docs/trace_format.md §6) and of the
binary encoder (`trace::binary::encode`, spec §10). The committed
`.json`/`.tbt` files are what `tests/trace_binary.rs` pins byte-for-byte;
rerun this script only when the spec itself changes, and review the
resulting diff against the spec tables by hand.

All float values in the corpus are short dyadic decimals so Python's
`repr` and Rust's shortest-roundtrip `Display` agree.

The recorded replay corpus (`replay/serve_v3.{json,tbt}`) cannot be
hand-authored — its bytes come from the engine's cost model — so this
script re-blesses it through `cargo test --test replay` (the golden
test writes the files when they are absent). Skipped with a notice when
no Rust toolchain is on PATH.
"""

import shutil
import struct
import subprocess
from pathlib import Path

HERE = Path(__file__).resolve().parent

# --- canonical JSON (spec §6) ----------------------------------------------


def jnum(f):
    f = float(f)
    if f != f or f in (float("inf"), float("-inf")):
        return "null"
    if f == int(f) and abs(f) < 9.0e15:
        return str(int(f))
    return repr(f)


def jstr(s):
    out = '"'
    for c in s:
        if c == '"':
            out += '\\"'
        elif c == "\\":
            out += "\\\\"
        elif c == "\n":
            out += "\\n"
        elif c == "\r":
            out += "\\r"
        elif c == "\t":
            out += "\\t"
        elif ord(c) < 0x20:
            out += "\\u%04x" % ord(c)
        else:
            out += c
    return out + '"'


def kernel_meta_json(m):
    parts = [
        '"kernel_name":' + jstr(m["kernel_name"]),
        '"family":' + jstr(m["family"]),
        '"aten_op":' + jstr(m["aten_op"]),
        '"shapes_key":' + jstr(m["shapes_key"]),
        '"grid":[' + ",".join(jnum(g) for g in m["grid"]) + "]",
        '"block":[' + ",".join(jnum(b) for b in m["block"]) + "]",
        '"lib":' + ("true" if m["lib"] else "false"),
        '"flops":' + jnum(m["flops"]),
        '"bytes":' + jnum(m["bytes"]),
    ]
    return "{" + ",".join(parts) + "}"


def args_json(kind, a):
    # Key orders mirror `ReplayArgs::to_json` (spec §4.2).
    if kind == "arrival":
        parts = [
            '"req":' + jnum(a["req"]),
            '"plen":' + jnum(a["plen"]),
            '"max_new":' + jnum(a["max_new"]),
            '"model":' + jstr(a["model"]),
        ]
    elif kind == "rng_draw":
        parts = ['"site":' + jstr(a["site"]), '"value":' + jnum(a["value"])]
    elif kind == "sched_decision":
        groups = ",".join(
            "[" + ",".join(jnum(i) for i in g) + "]" for g in a["admitted"]
        )
        parts = [
            '"step":' + jnum(a["step"]),
            '"admitted":[' + groups + "]",
            '"preempted":[' + ",".join(jnum(i) for i in a["preempted"]) + "]",
        ]
        # Spec v4: `shed` slots between `preempted` and `batch`, and is
        # omitted when empty so v3 captures stay byte-identical.
        if a.get("shed"):
            parts.append('"shed":[' + ",".join(jnum(i) for i in a["shed"]) + "]")
        parts.append('"batch":' + jnum(a["batch"]))
    elif kind == "fault":
        parts = [
            '"kind":' + jstr(a["kind"]),
            '"target":' + jstr(a["target"]),
            '"onset_us":' + jnum(a["onset_us"]),
            '"dur_us":' + jnum(a["dur_us"]),
            '"magnitude":' + jnum(a["magnitude"]),
        ]
    else:
        raise ValueError(f"kind {kind} carries no args")
    return "{" + ",".join(parts) + "}"


def event_json(e):
    track = -1 if e["track"] == "host" else e["track"]
    parts = [
        '"kind":' + jstr(e["kind"]),
        '"name":' + jstr(e["name"]),
        '"ts":' + jnum(e["ts"]),
        '"dur":' + jnum(e["dur"]),
        '"corr":' + jnum(e["corr"]),
        '"track":' + jnum(track),
    ]
    if e.get("device") is not None:
        parts.append('"device":' + jnum(e["device"]))
    if e.get("args") is not None:
        parts.append('"args":' + args_json(e["kind"], e["args"]))
    if e.get("meta") is not None:
        parts.append('"meta":' + kernel_meta_json(e["meta"]))
    return "{" + ",".join(parts) + "}"


def trace_json(t):
    m = t["meta"]
    meta = "{" + ",".join(
        [
            '"platform":' + jstr(m["platform"]),
            '"model":' + jstr(m["model"]),
            '"phase":' + jstr(m["phase"]),
            '"batch":' + jnum(m["batch"]),
            '"seq":' + jnum(m["seq"]),
            '"m_tokens":' + jnum(m["m_tokens"]),
            '"wall_us":' + jnum(m["wall_us"]),
        ]
    ) + "}"
    events = "[" + ",".join(event_json(e) for e in t["events"]) + "]"
    return '{"meta":' + meta + ',"events":' + events + "}"


# --- binary dialect (spec §10) ---------------------------------------------

KIND_CODE = {
    "torch_op": 0,
    "aten_op": 1,
    "runtime_api": 2,
    "kernel": 3,
    "nvtx": 4,
    "arrival": 5,
    "rng_draw": 6,
    "sched_decision": 7,
    "clock_jump": 8,
    "fault": 9,
}


def varint(v):
    out = b""
    while True:
        byte = v & 0x7F
        v >>= 7
        if v == 0:
            return out + bytes([byte])
        out += bytes([byte | 0x80])


def bstr(s):
    raw = s.encode("utf-8")
    return varint(len(raw)) + raw


def bf64(v):
    return struct.pack("<d", float(v))


def trace_binary(t):
    m = t["meta"]
    out = b"TXBT" + struct.pack("<H", 1) + struct.pack("<H", 0)
    out += (
        b"\x01"
        + bstr(m["platform"])
        + bstr(m["model"])
        + bstr(m["phase"])
        + varint(m["batch"])
        + varint(m["seq"])
        + varint(m["m_tokens"])
    )
    for e in t["events"]:
        presence = 0
        if e.get("device") is not None:
            presence |= 0b001
        if e.get("meta") is not None:
            presence |= 0b010
        if e.get("args") is not None:
            presence |= 0b100
        # Spec v4 PRESENT_SHED: set only for a non-empty shed list.
        if e["kind"] == "sched_decision" and e.get("args", {}).get("shed"):
            presence |= 0b1000
        out += b"\x02" + bytes([KIND_CODE[e["kind"]], presence])
        out += bstr(e["name"]) + bf64(e["ts"]) + bf64(e["dur"])
        out += varint(e["corr"])
        out += varint(0 if e["track"] == "host" else e["track"] + 1)
        if e.get("device") is not None:
            out += varint(e["device"])
        a = e.get("args")
        if a is not None:
            if e["kind"] == "arrival":
                out += varint(a["req"]) + varint(a["plen"]) + varint(a["max_new"])
                out += bstr(a["model"])
            elif e["kind"] == "rng_draw":
                out += bstr(a["site"]) + bf64(a["value"])
            elif e["kind"] == "sched_decision":
                out += varint(a["step"]) + varint(len(a["admitted"]))
                for group in a["admitted"]:
                    out += varint(len(group))
                    for i in group:
                        out += varint(i)
                out += varint(len(a["preempted"]))
                for i in a["preempted"]:
                    out += varint(i)
                if a.get("shed"):
                    out += varint(len(a["shed"]))
                    for i in a["shed"]:
                        out += varint(i)
                out += varint(a["batch"])
            elif e["kind"] == "fault":
                out += bstr(a["kind"]) + bstr(a["target"])
                out += bf64(a["onset_us"]) + bf64(a["dur_us"])
                out += bf64(a["magnitude"])
            else:
                raise ValueError(f"kind {e['kind']} carries no args")
        km = e.get("meta")
        if km is not None:
            out += bstr(km["kernel_name"]) + bstr(km["family"])
            out += bstr(km["aten_op"]) + bstr(km["shapes_key"])
            for g in km["grid"]:
                out += varint(g)
            for b in km["block"]:
                out += varint(b)
            out += bytes([1 if km["lib"] else 0])
            out += bf64(km["flops"]) + bf64(km["bytes"])
    out += b"\x03" + struct.pack("<Q", len(t["events"])) + bf64(m["wall_us"]) + b"TXBE"
    return out


# --- the corpus ------------------------------------------------------------

# v1_min: a spec-v1 trace — single device, no `device` field anywhere;
# one full TorchOp→AtenOp→RuntimeApi→Kernel chain plus an NVTX range.
V1_MIN = {
    "meta": {
        "platform": "h100",
        "model": "gpt2",
        "phase": "decode",
        "batch": 1,
        "seq": 128,
        "m_tokens": 4,
        "wall_us": 42.5,
    },
    "events": [
        {"kind": "torch_op", "name": "decode.step", "ts": 0.0, "dur": 10.5, "corr": 1, "track": "host"},
        {"kind": "aten_op", "name": "aten::mm", "ts": 0.5, "dur": 2.25, "corr": 1, "track": "host"},
        {"kind": "runtime_api", "name": "cudaLaunchKernel", "ts": 2.75, "dur": 1.5, "corr": 1, "track": "host"},
        {
            "kind": "kernel",
            "name": "ampere_bf16_gemm",
            "ts": 4.25,
            "dur": 6.25,
            "corr": 1,
            "track": 0,
            "meta": {
                "kernel_name": "ampere_bf16_gemm",
                "family": "gemm_cublas",
                "aten_op": "aten::mm",
                "shapes_key": "f32[8,64]x[64,64]",
                "grid": [8, 4, 1],
                "block": [128, 1, 1],
                "lib": True,
                "flops": 65536.0,
                "bytes": 32768.0,
            },
        },
        {"kind": "nvtx", "name": "phase2.replay", "ts": 0.0, "dur": 42.5, "corr": 0, "track": "host"},
    ],
}

# v2_multi: spec-v2 features — `device` stamps, multiple streams per
# device, an unmediated kernel, fractional byte counts, and names that
# exercise JSON escaping (quote, newline) and non-ASCII UTF-8.
V2_MULTI = {
    "meta": {
        "platform": "h200",
        "model": "olmoe-1b-7b",
        "phase": "serve",
        "batch": 2,
        "seq": 64,
        "m_tokens": 8,
        "wall_us": 100.25,
    },
    "events": [
        {"kind": "torch_op", "name": 'serve.prefill "réplica"\nstep', "ts": 0.0, "dur": 5.5, "corr": 1, "track": "host", "device": 0},
        {
            "kind": "kernel",
            "name": "moe_dispatch",
            "ts": 1.5,
            "dur": 3.5,
            "corr": 1,
            "track": 1,
            "device": 0,
            "meta": {
                "kernel_name": "moe_dispatch",
                "family": "moe_routing",
                "aten_op": "aten::topk",
                "shapes_key": "bf16[2,64,8]",
                "grid": [64, 1, 1],
                "block": [256, 1, 1],
                "lib": False,
                "flops": 0.0,
                "bytes": 1024.5,
            },
        },
        {"kind": "aten_op", "name": "aten::topk", "ts": 0.25, "dur": 1.25, "corr": 2, "track": "host", "device": 1},
        {"kind": "runtime_api", "name": "cudaLaunchKernel", "ts": 2.0, "dur": 0.25, "corr": 2, "track": "host", "device": 1},
        {
            "kind": "kernel",
            "name": "gemm_k",
            "ts": 2.5,
            "dur": 4.75,
            "corr": 2,
            "track": 2,
            "device": 1,
            "meta": {
                "kernel_name": "gemm_k",
                "family": "gemm_cublas",
                "aten_op": "aten::mm",
                "shapes_key": "f32[2,64]x[64,64]",
                "grid": [2, 2, 1],
                "block": [128, 1, 1],
                "lib": True,
                "flops": 1048576.0,
                "bytes": 65536.0,
            },
        },
        {"kind": "nvtx", "name": "phase", "ts": 0.0, "dur": 100.25, "corr": 0, "track": "host"},
    ],
}


# v3_replay: spec-v3 recording events — `arrival`, `rng_draw`,
# `sched_decision` and `clock_jump` alongside an observation chain.
# Recording events always carry correlation id 0 (they belong to no
# kernel chain); `clock_jump` is the one new kind with no args payload.
V3_REPLAY = {
    "meta": {
        "platform": "h200",
        "model": "gpt2",
        "phase": "serve",
        "batch": 0,
        "seq": 0,
        "m_tokens": 0,
        "wall_us": 99.5,
    },
    "events": [
        {
            "kind": "arrival",
            "name": "arrival",
            "ts": 0.0,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "args": {"req": 0, "plen": 32, "max_new": 4, "model": "gpt2"},
        },
        {
            "kind": "clock_jump",
            "name": "clock_jump",
            "ts": 0.0,
            "dur": 2.5,
            "corr": 0,
            "track": "host",
            "device": 1,
        },
        {
            "kind": "rng_draw",
            "name": "rng_draw",
            "ts": 2.5,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "args": {"site": "prep::prefill_b1", "value": 30.75},
        },
        {
            "kind": "sched_decision",
            "name": "sched_decision",
            "ts": 2.5,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "device": 1,
            "args": {
                "step": 1,
                "admitted": [[0, 2], [1]],
                "preempted": [3],
                "batch": 4,
            },
        },
        {"kind": "torch_op", "name": "serve.decode", "ts": 2.5, "dur": 6.0, "corr": 1, "track": "host"},
        {
            "kind": "kernel",
            "name": "decode_b4",
            "ts": 4.0,
            "dur": 4.5,
            "corr": 1,
            "track": 0,
            "meta": {
                "kernel_name": "decode_b4",
                "family": "gemm_cublas",
                "aten_op": "aten::mm",
                "shapes_key": "bf16[4,768]",
                "grid": [4, 1, 1],
                "block": [128, 1, 1],
                "lib": True,
                "flops": 4096.0,
                "bytes": 2048.0,
            },
        },
        {
            "kind": "rng_draw",
            "name": "rng_draw",
            "ts": 8.5,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "args": {"site": "exec::decode_b4", "value": -0.625},
        },
    ],
}


# v4_fault: spec-v4 fault injection — one `fault` event per window kind
# (the full window re-armable from `args`), a deadline-shed scheduler
# decision carrying the non-empty `shed` list, and a v3-shaped decision
# whose empty shed must leave both encodings exactly v3. Fault events
# carry correlation id 0 and the recording replica's `device` stamp.
V4_FAULT = {
    "meta": {
        "platform": "h200",
        "model": "gpt2",
        "phase": "serve",
        "batch": 0,
        "seq": 0,
        "m_tokens": 0,
        "wall_us": 5000.25,
    },
    "events": [
        {
            "kind": "fault",
            "name": "fault::device_stall",
            "ts": 1000.0,
            "dur": 500.5,
            "corr": 0,
            "track": "host",
            "device": 0,
            "args": {
                "kind": "device_stall",
                "target": "stream:*",
                "onset_us": 1000.0,
                "dur_us": 500.5,
                "magnitude": 3.5,
            },
        },
        {
            "kind": "fault",
            "name": "fault::host_jitter",
            "ts": 0.0,
            "dur": 2000.0,
            "corr": 0,
            "track": "host",
            "device": 0,
            "args": {
                "kind": "host_jitter",
                "target": "host:all",
                "onset_us": 0.0,
                "dur_us": 2000.0,
                "magnitude": 1.5,
            },
        },
        {
            "kind": "fault",
            "name": "fault::launch_fail",
            "ts": 250.25,
            "dur": 100.0,
            "corr": 0,
            "track": "host",
            "device": 0,
            "args": {
                "kind": "launch_fail",
                "target": "launch",
                "onset_us": 250.25,
                "dur_us": 100.0,
                "magnitude": 2.0,
            },
        },
        {
            "kind": "fault",
            "name": "fault::kv_pressure",
            "ts": 0.0,
            "dur": 4000.0,
            "corr": 0,
            "track": "host",
            "device": 0,
            "args": {
                "kind": "kv_pressure",
                "target": "kv",
                "onset_us": 0.0,
                "dur_us": 4000.0,
                "magnitude": 0.5,
            },
        },
        {
            "kind": "arrival",
            "name": "arrival",
            "ts": 0.0,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "args": {"req": 0, "plen": 16, "max_new": 2, "model": "gpt2"},
        },
        {
            "kind": "sched_decision",
            "name": "sched_decision",
            "ts": 500.0,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "device": 0,
            "args": {
                "step": 1,
                "admitted": [[0], [1, 2]],
                "preempted": [4],
                "shed": [3, 5],
                "batch": 3,
            },
        },
        {
            "kind": "sched_decision",
            "name": "sched_decision",
            "ts": 600.0,
            "dur": 0.0,
            "corr": 0,
            "track": "host",
            "device": 0,
            "args": {
                "step": 2,
                "admitted": [],
                "preempted": [],
                "shed": [],
                "batch": 3,
            },
        },
    ],
}


def bless_replay_corpus():
    """Re-record `replay/serve_v3.{json,tbt}` through the Rust stack.

    The golden test in `tests/replay.rs` writes the corpus when absent
    and byte-checks it when present, so re-blessing = delete + run it.
    """
    replay_dir = HERE / "replay"
    cargo = shutil.which("cargo")
    if cargo is None:
        print("cargo not on PATH — skipped re-blessing replay/serve_v3.{json,tbt}")
        return
    for f in ["serve_v3.json", "serve_v3.tbt"]:
        (replay_dir / f).unlink(missing_ok=True)
    subprocess.run(
        [cargo, "test", "-q", "--test", "replay",
         "golden_replay_corpus_is_a_byte_fixed_point_in_both_dialects"],
        cwd=HERE.parent.parent,
        check=True,
    )
    for f in ["serve_v3.json", "serve_v3.tbt"]:
        path = replay_dir / f
        print(f"blessed replay/{f} ({path.stat().st_size} bytes)")


def main():
    corpus = [
        ("v1_min", V1_MIN),
        ("v2_multi", V2_MULTI),
        ("v3_replay", V3_REPLAY),
        ("v4_fault", V4_FAULT),
    ]
    for name, trace in corpus:
        (HERE / f"{name}.json").write_bytes(trace_json(trace).encode("utf-8"))
        (HERE / f"{name}.tbt").write_bytes(trace_binary(trace))
        print(f"wrote {name}.json ({len(trace_json(trace).encode('utf-8'))} bytes), "
              f"{name}.tbt ({len(trace_binary(trace))} bytes)")
    bless_replay_corpus()


if __name__ == "__main__":
    main()
