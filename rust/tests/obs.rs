//! Integration: the live telemetry plane (`obs`).
//!
//! Three locks on the online-decomposition contract (DESIGN.md §14):
//!
//! * **bit-identity** — the streaming decomposer's end-of-run totals
//!   equal the post-hoc two-phase pipeline (`taxbreak analyze`) on the
//!   same trace, bit for bit, on eager sim traces and spec-v3 serving
//!   captures alike;
//! * a **property suite** — for arbitrary loadgen configurations and
//!   window sizes, the per-window slices partition the aggregate:
//!   integer fields sum exactly, float fields to float-fold tolerance;
//! * a **spec-drift test** — every metric name the registry exposes is
//!   documented in `docs/metrics.md`, and vice versa.

use std::path::PathBuf;

use taxbreak::hardware::Platform;
use taxbreak::obs::{self, OnlineReport};
use taxbreak::prop_assert;
use taxbreak::serving::loadgen::LenDist;
use taxbreak::serving::{run_sim_loadgen, LoadgenConfig, SchedulerConfig};
use taxbreak::sim::simulate;
use taxbreak::taxbreak::{analyze, Decomposition, ReplayConfig, SimReplayBackend};
use taxbreak::trace::Trace;
use taxbreak::util::prop::forall;

/// The post-hoc reference: the same pipeline `taxbreak analyze --trace`
/// runs (same seed, same replay config).
fn posthoc(trace: &Trace) -> Decomposition {
    let platform = Platform::by_name(&trace.meta.platform).unwrap();
    let mut backend = SimReplayBackend::new(platform, obs::ANALYZE_REPLAY_SEED);
    analyze(trace, &mut backend, &ReplayConfig::fast()).decomposition
}

fn online(trace: &Trace, window_us: f64) -> OnlineReport {
    let platform = Platform::by_name(&trace.meta.platform).unwrap();
    obs::snapshot_of_trace(trace, platform, window_us).0
}

/// Bitwise equality on every scalar, exact equality on the per-family
/// and per-device partitions.
fn assert_bit_identical(got: &Decomposition, want: &Decomposition) {
    assert_eq!(got.n_kernels, want.n_kernels, "n_kernels");
    for (x, y, name) in [
        (got.t_py_us, want.t_py_us, "t_py_us"),
        (got.t_base_us, want.t_base_us, "t_base_us"),
        (got.dct_us, want.dct_us, "dct_us"),
        (got.dkt_us, want.dkt_us, "dkt_us"),
        (got.device_active_us, want.device_active_us, "device_active_us"),
        (got.e2e_us, want.e2e_us, "e2e_us"),
        (got.floor_us, want.floor_us, "floor_us"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{name}: online {x} vs post-hoc {y}");
    }
    assert_eq!(got.per_family, want.per_family, "per-family partition");
    assert_eq!(got.per_device, want.per_device, "per-device partition");
}

#[test]
fn online_totals_are_bit_identical_to_decompose_on_the_bundled_eager_trace() {
    let cfg = taxbreak::whatif::bundled::by_name("moe-decode").unwrap();
    let trace = simulate(
        &cfg.model_spec().unwrap(),
        &cfg.platform_spec().unwrap(),
        &cfg.workload(),
        cfg.seed,
    );
    let rep = online(&trace, 0.0);
    assert_bit_identical(&rep.totals, &posthoc(&trace));
    // W <= 0 collapses the series to the single whole-run window.
    assert_eq!(rep.windows.len(), 1);
    assert_eq!(rep.windows[0].start_us, 0.0);
    assert_eq!(rep.windows[0].end_us.to_bits(), rep.totals.e2e_us.to_bits());
    assert_eq!(rep.windows[0].n_kernels, rep.totals.n_kernels);
    // Eager traces carry no scheduler, so no recording events and no
    // token proxy.
    assert_eq!(rep.counts.recording, 0);
    assert_eq!(rep.launches_per_token(), 0.0);
}

/// The committed golden replay corpus (`tests/golden/replay/serve_v3.tbt`)
/// pins the engine's bytes across refactors; this pins the *analysis*
/// on those bytes: the streaming decomposer and the post-hoc pipeline
/// must stay bit-identical on the exact committed capture, so a hot-path
/// change (e.g. symbol interning of kernel metadata) that perturbed
/// either path would fail here even if both paths drifted together on
/// freshly generated traces.
#[test]
fn online_totals_are_bit_identical_to_decompose_on_the_golden_replay_corpus() {
    // The corpus workload of tests/replay.rs::golden_recording —
    // regenerated here so the check runs even on a fresh checkout
    // where the blessing test hasn't written the files yet.
    let cfg = LoadgenConfig {
        requests: 8,
        rate_per_s: 1500.0,
        prompt_len: LenDist::Uniform { lo: 8, hi: 24 },
        output_len: LenDist::Uniform { lo: 2, hi: 6 },
        seed: 42,
        devices: 2,
        streams: 2,
        sched: SchedulerConfig { kv_pages: 128, ..SchedulerConfig::default() },
        capture: true,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
    let trace = report.runs[0].trace.clone().unwrap();
    let want = posthoc(&trace);
    let rep = online(&trace, 0.0);
    assert_bit_identical(&rep.totals, &want);
    assert!(want.n_kernels > 0);
    let h = want.hdbi();
    assert!(h > 0.0 && h < 1.0, "golden corpus HDBI out of range: {h}");

    // When the blessed on-disk corpus is present, the decomposition of
    // its *bytes* must agree too — a drift in the wire format or in
    // interned-symbol reconstruction from disk would surface here.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("replay")
        .join("serve_v3.tbt");
    if path.exists() {
        let from_disk = Trace::load(&path).unwrap();
        assert_bit_identical(&posthoc(&from_disk), &want);
    } else {
        eprintln!("serve_v3.tbt not blessed yet — skipped the on-disk half");
    }
}

#[test]
fn online_totals_are_bit_identical_to_decompose_on_v3_serving_captures() {
    let cfg = LoadgenConfig {
        requests: 8,
        rate_per_s: 1500.0,
        seed: 42,
        devices: 2,
        streams: 2,
        sched: SchedulerConfig { kv_pages: 128, ..SchedulerConfig::default() },
        capture: true,
        metrics: true,
        window_us: 400.0,
        ..LoadgenConfig::default()
    };
    let models = ["gpt2".to_string(), "olmoe-1b-7b".to_string()];
    let report = run_sim_loadgen(&models, "h200", &cfg).unwrap();
    for run in &report.runs {
        let trace = run.trace.as_ref().unwrap();
        let want = posthoc(trace);

        // The snapshot taken post-hoc from the capture...
        let rep = online(trace, 0.0);
        assert_bit_identical(&rep.totals, &want);
        assert!(rep.counts.recording > 0, "v3 recording events are visible to the counters");
        assert!(rep.counts.batch_sum > 0);
        assert!(rep.launches_per_token() > 0.0);

        // ...and the one streamed live during the run agree with the
        // two-phase pipeline bit for bit.
        let live = &run.telemetry.as_ref().unwrap().online;
        assert_bit_identical(&live.totals, &want);
        assert!(live.windows.len() > 1, "windowed series splits the run");
    }
}

/// Float components are re-summed per window in a different order than
/// the flat fold, so the partition is exact for integers and
/// float-fold-tolerant for the `us` components.
fn assert_windows_partition(rep: &OnlineReport) {
    let t = &rep.totals;
    let sum = |f: fn(&taxbreak::obs::WindowSlice) -> f64| -> f64 {
        rep.windows.iter().map(f).sum()
    };
    let n: usize = rep.windows.iter().map(|w| w.n_kernels).sum();
    assert_eq!(n, t.n_kernels, "kernel counts partition exactly");
    let toks: usize = rep.windows.iter().map(|w| w.tokens).sum();
    assert_eq!(toks, rep.counts.batch_sum, "token counts partition exactly");
    let close = |a: f64, b: f64, name: &str| {
        let tol = 1e-9 * b.abs().max(1.0);
        assert!((a - b).abs() <= tol, "{name}: windows sum {a} vs totals {b}");
    };
    close(sum(|w| w.t_py_us), t.t_py_us, "t_py_us");
    close(sum(|w| w.t_base_us), t.t_base_us, "t_base_us");
    close(sum(|w| w.dct_us), t.dct_us, "dct_us");
    close(sum(|w| w.dkt_us), t.dkt_us, "dkt_us");
    close(sum(|w| w.device_active_us), t.device_active_us, "device_active_us");
    for p in 0..2 {
        let inv: usize = rep.windows.iter().map(|w| w.phases[p].invocations).sum();
        assert_eq!(inv, rep.phase_totals[p].invocations, "phase {p} invocations");
        let orch: f64 = rep.windows.iter().map(|w| w.phases[p].orchestration_us).sum();
        close(orch, rep.phase_totals[p].orchestration_us, "phase orchestration_us");
        let dev: f64 = rep.windows.iter().map(|w| w.phases[p].device_us).sum();
        close(dev, rep.phase_totals[p].device_us, "phase device_us");
    }
    // Windows are half-open [k·W, (k+1)·W), ascending, non-overlapping.
    if rep.window_us > 0.0 {
        for pair in rep.windows.windows(2) {
            assert!(pair[0].index < pair[1].index);
        }
        for w in &rep.windows {
            assert_eq!(w.start_us, w.index as f64 * rep.window_us);
            assert_eq!(w.end_us, w.start_us + rep.window_us);
        }
    }
}

#[test]
fn prop_window_slices_partition_the_aggregate_for_arbitrary_configs() {
    forall("windowed slices partition the online decomposition", 8, |g| {
        let devices = g.usize_in(1, 3);
        let cfg = LoadgenConfig {
            requests: g.usize_in(devices, 8),
            rate_per_s: *g.choice(&[0.0, 800.0, 2500.0]),
            prompt_len: LenDist::Uniform { lo: g.usize_in(1, 8), hi: g.usize_in(8, 24) },
            output_len: LenDist::Uniform { lo: 1, hi: g.usize_in(1, 6) },
            seed: g.u64(),
            devices,
            streams: g.usize_in(1, 2),
            sched: SchedulerConfig { kv_pages: 64 * devices, ..SchedulerConfig::default() },
            capture: true,
            ..LoadgenConfig::default()
        };
        let model = g.choice(&["gpt2", "olmoe-1b-7b"]).to_string();
        let platform = g.choice(&["h100", "h200"]).to_string();
        let report = run_sim_loadgen(&[model], &platform, &cfg).unwrap();
        let trace = report.runs[0].trace.as_ref().unwrap();
        let want = posthoc(trace);

        // Any window size — including one that splinters the run into
        // hundreds of slices — leaves the totals bit-identical and the
        // partition exact.
        let frac = *g.choice(&[0.0, 0.03, 0.2, 0.7, 2.0]);
        let rep = online(trace, trace.e2e_us() * frac);
        assert_bit_identical(&rep.totals, &want);
        assert_windows_partition(&rep);
        prop_assert!(
            g,
            rep.totals.n_kernels > 0,
            "a served run must launch kernels"
        );
        true
    });
}

#[test]
fn moe_serving_windows_separate_prefill_from_decode_hdbi() {
    let cfg = LoadgenConfig {
        requests: 6,
        rate_per_s: 0.0,
        seed: 3,
        capture: true,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&["olmoe-1b-7b".to_string()], "h200", &cfg).unwrap();
    let trace = report.runs[0].trace.as_ref().unwrap();
    let rep = online(trace, trace.e2e_us() / 12.0);

    let [pf, dec] = &rep.phase_totals;
    assert!(pf.invocations > 0 && dec.invocations > 0, "both phases ran");
    assert!(
        (pf.hdbi() - dec.hdbi()).abs() > 0.01,
        "prefill ({:.3}) and decode ({:.3}) HDBI must be distinct",
        pf.hdbi(),
        dec.hdbi()
    );
    assert!(
        pf.hdbi() > dec.hdbi(),
        "MoE decode is more host-bound than prefill (the paper's contrast)"
    );
    // The per-window series resolves the contrast over time: windows
    // dominated by each phase exist, and their HDBI values spread.
    assert!(rep.windows.iter().any(|w| w.phases[0].invocations > w.phases[1].invocations));
    assert!(rep.windows.iter().any(|w| w.phases[1].invocations > w.phases[0].invocations));
    let active = rep.windows.iter().filter(|w| w.n_kernels > 0);
    let hdbis: Vec<f64> = active.map(|w| w.hdbi()).collect();
    let spread = hdbis.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - hdbis.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread > 0.01, "per-window HDBI is flat ({hdbis:?})");
}

/// Every metric the registry can emit, by name. `docs/metrics.md` is
/// the user-facing contract: adding, renaming or dropping a metric
/// must update both this list and the doc, or this test fails.
const METRIC_NAMES: [&str; 36] = [
    "taxbreak_events_total",
    "taxbreak_recording_events_total",
    "taxbreak_arrivals_total",
    "taxbreak_rng_draws_total",
    "taxbreak_clock_jumps_total",
    "taxbreak_clock_jump_us_total",
    "taxbreak_sched_steps_total",
    "taxbreak_sched_admitted_total",
    "taxbreak_sched_preempted_total",
    "taxbreak_output_tokens_total",
    "taxbreak_kernel_launches_total",
    "taxbreak_t_fw_us_total",
    "taxbreak_t_lib_us_total",
    "taxbreak_t_launch_us_total",
    "taxbreak_orchestration_us_total",
    "taxbreak_device_active_us_total",
    "taxbreak_e2e_us",
    "taxbreak_hdbi",
    "taxbreak_phase_hdbi",
    "taxbreak_kernel_launches_per_output_token",
    "taxbreak_window_hdbi",
    "taxbreak_stream_active_us",
    "taxbreak_stream_idle_fraction",
    "taxbreak_probe_steps_total",
    "taxbreak_sheds_total",
    "taxbreak_launch_retries_total",
    "taxbreak_failed_requests_total",
    "taxbreak_deadline_misses_total",
    "taxbreak_kv_pages_used",
    "taxbreak_kv_pages_reserved",
    "taxbreak_kv_pages_free",
    "taxbreak_kv_pages_total",
    "taxbreak_kv_occupancy_ratio",
    "taxbreak_sched_queue_depth",
    "taxbreak_ttft_us",
    "taxbreak_tpot_us",
];

fn metrics_doc() -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("docs")
        .join("metrics.md");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

#[test]
fn metrics_doc_names_every_metric_and_nothing_else() {
    let doc = metrics_doc();
    for name in METRIC_NAMES {
        assert!(doc.contains(&format!("`{name}`")), "docs/metrics.md is missing `{name}`");
    }
    // Every `taxbreak_*` identifier the doc names is a real metric.
    for line in doc.lines() {
        let mut rest = line;
        while let Some(i) = rest.find("`taxbreak_") {
            let tail = &rest[i + 1..];
            let end = tail.find('`').unwrap_or(tail.len());
            let name = &tail[..end];
            assert!(
                METRIC_NAMES.contains(&name),
                "docs/metrics.md documents unknown metric `{name}`"
            );
            rest = &tail[end..];
        }
    }
}

#[test]
fn a_metrics_run_exposes_exactly_the_documented_names() {
    let cfg = LoadgenConfig {
        requests: 4,
        rate_per_s: 0.0,
        capture: true,
        metrics: true,
        window_us: 500.0,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
    let reg = report.metrics_registry().unwrap();
    let text = reg.prometheus_text();
    for name in METRIC_NAMES {
        assert!(
            text.contains(&format!("# TYPE {name} ")),
            "a metrics run must expose `{name}`"
        );
    }
    // And nothing undocumented leaks out.
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let name = rest.split_whitespace().next().unwrap();
            assert!(METRIC_NAMES.contains(&name), "undocumented metric `{name}` exposed");
        }
    }
}
