//! Real-mode integration: AOT artifacts → PJRT engine → serving loop.
//!
//! The whole file is gated on the `real-pjrt` feature (the default
//! build has no PJRT engine); additionally the tests need `artifacts/`
//! (run `make artifacts`) and skip gracefully when it is absent so
//! `cargo test --features real-pjrt` works pre-build.
#![cfg(feature = "real-pjrt")]

use std::path::PathBuf;

use taxbreak::runtime::{ArtifactIndex, Engine, PjrtReplayBackend};
use taxbreak::serving::{run_server_demo, ModelBackend};
use taxbreak::taxbreak::phase2::{ReplayBackend, ReplayConfig};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("index.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn engine_loads_all_variants() {
    require_artifacts!();
    for variant in ["dense_fused", "dense_eager", "moe"] {
        let e = Engine::load(&artifacts_dir(), variant).unwrap();
        assert_eq!(e.variant(), variant);
        assert!(e.config().vocab >= 256);
        assert_eq!(e.config().max_seq, 128);
        assert_eq!(e.decode_buckets(), vec![1, 4]);
    }
}

#[test]
fn prefill_decode_consistency_on_pjrt() {
    // Teacher-forcing: decoding the prompt token-by-token must produce
    // the same final logits as prefilling the whole prompt — the L2
    // model invariant, verified end-to-end through HLO text + PJRT.
    require_artifacts!();
    let mut e = Engine::load(&artifacts_dir(), "dense_fused").unwrap();
    let prompt: Vec<i32> = (1..=12).collect();

    let full = e.prefill(&[prompt.clone()]).unwrap();
    let logits_full = &full.logits[0];

    let head = e.prefill(&[prompt[..11].to_vec()]).unwrap();
    let step = e.decode(head.cache, 11, &[prompt[11]]).unwrap();
    let logits_step = &step.logits[0];

    let mut max_diff = 0f32;
    for (a, b) in logits_full.iter().zip(logits_step.iter()) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "prefill/decode mismatch: {max_diff}");
}

#[test]
fn fused_and_eager_variants_agree_numerically() {
    // Fig. 9's correctness precondition: the Pallas fused kernel and
    // the eager jnp path share weights and must agree.
    require_artifacts!();
    let mut fused = Engine::load(&artifacts_dir(), "dense_fused").unwrap();
    let mut eager = Engine::load(&artifacts_dir(), "dense_eager").unwrap();
    let prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let a = fused.prefill(&[prompt.clone()]).unwrap();
    let b = eager.prefill(&[prompt]).unwrap();
    let mut max_diff = 0f32;
    for (x, y) in a.logits[0].iter().zip(b.logits[0].iter()) {
        max_diff = max_diff.max((x - y).abs());
    }
    assert!(max_diff < 1e-2, "fused vs eager logits diverge: {max_diff}");
}

#[test]
fn greedy_generation_is_deterministic() {
    require_artifacts!();
    let mut e = Engine::load(&artifacts_dir(), "dense_fused").unwrap();
    let gen = |e: &mut Engine| -> Vec<i32> {
        let prompt: Vec<i32> = vec![7, 8, 9, 10];
        let out = e.prefill(&[prompt.clone()]).unwrap();
        let mut cache = out.cache;
        let mut tok = Engine::argmax(&out.logits[0]);
        let mut tokens = vec![tok];
        for pos in prompt.len()..prompt.len() + 5 {
            let d = e.decode(cache, pos, &[tok]).unwrap();
            cache = d.cache;
            tok = Engine::argmax(&d.logits[0]);
            tokens.push(tok);
        }
        tokens
    };
    let a = gen(&mut e);
    let b = gen(&mut e);
    assert_eq!(a, b);
    assert!(a.iter().all(|&t| (0..e.config().vocab as i32).contains(&t)));
}

#[test]
fn batched_prefill_matches_single() {
    require_artifacts!();
    let mut e = Engine::load(&artifacts_dir(), "dense_fused").unwrap();
    let p1: Vec<i32> = vec![11, 22, 33, 44, 55];
    let p2: Vec<i32> = vec![9, 8, 7];
    let batched = e.prefill(&[p1.clone(), p2.clone()]).unwrap();
    let solo1 = e.prefill(&[p1]).unwrap();
    let solo2 = e.prefill(&[p2]).unwrap();
    for (a, b) in [(&batched.logits[0], &solo1.logits[0]),
                   (&batched.logits[1], &solo2.logits[0])] {
        let mut max_diff = 0f32;
        for (x, y) in a.iter().zip(b.iter()) {
            max_diff = max_diff.max((x - y).abs());
        }
        assert!(max_diff < 1e-3, "batched vs solo logits: {max_diff}");
    }
}

#[test]
fn null_kernel_floor_is_measurable() {
    require_artifacts!();
    let mut e = Engine::load(&artifacts_dir(), "dense_fused").unwrap();
    let mut backend = PjrtReplayBackend::new(&mut e);
    let floors = backend.null_kernel(&ReplayConfig {
        warmup: 3,
        runs: 15,
    });
    assert_eq!(floors.len(), 15);
    // CPU PJRT floor: positive, stable within an order of magnitude.
    let mean = floors.iter().sum::<f64>() / floors.len() as f64;
    assert!(mean > 1.0 && mean < 10_000.0, "floor {mean} us");
}

#[test]
fn serving_demo_end_to_end() {
    require_artifacts!();
    let s = run_server_demo(&artifacts_dir(), "dense_fused", 6, 4, 99).unwrap();
    assert_eq!(s.requests, 6);
    assert!(s.tokens_generated >= 6 * 4);
    assert!(s.throughput_tps() > 0.0);
    assert!(s.ttft_us.mean > 0.0);
    assert!(s.wall_us > 0.0);
    assert!(s.hdbi() > 0.0 && s.hdbi() <= 1.0);
}

#[test]
fn recorder_trace_is_analyzable() {
    require_artifacts!();
    let mut e = Engine::load(&artifacts_dir(), "dense_fused").unwrap();
    let prompt: Vec<i32> = vec![1, 2, 3, 4];
    let out = e.prefill(&[prompt]).unwrap();
    let _ = e.decode(out.cache, 4, &[5]).unwrap();
    let trace = e.take_trace();
    assert_eq!(trace.kernel_count(), 2); // one per executable invocation
    taxbreak::taxbreak::phase1::validate_trace(&trace).unwrap();
    let (host, dev, n) = taxbreak::serving::real_trace_split(&trace);
    assert_eq!(n, 2);
    assert!(host > 0.0 && dev > 0.0);
}

#[test]
fn engine_implements_backend_contract() {
    require_artifacts!();
    let mut e = Engine::load(&artifacts_dir(), "moe").unwrap();
    let (next, cache) = e.prefill_group(&[vec![1, 2, 3], vec![4, 5, 6]]).unwrap();
    assert_eq!(next.len(), 2);
    let (next2, _cache) = e.decode_group(cache, 3, &next).unwrap();
    assert_eq!(next2.len(), 2);
}

#[test]
fn artifact_index_enumerates_buckets() {
    require_artifacts!();
    let idx = ArtifactIndex::load(&artifacts_dir()).unwrap();
    assert_eq!(idx.of_variant("dense_fused", "prefill").count(), 4);
    assert_eq!(idx.of_variant("dense_fused", "decode").count(), 2);
    assert_eq!(idx.of_variant("moe", "prefill").count(), 4);
}
