//! Cross-format lock on the binary trace dialect (`.tbt`).
//!
//! The committed golden corpus under `tests/golden/` pins both dialects
//! byte-for-byte in both directions (JSON → binary and binary → JSON);
//! a property suite checks arbitrary traces survive the round trip with
//! the decomposition bit-identical; a robustness suite checks every
//! way a binary file can be damaged yields a typed
//! [`BinaryTraceError`] — never a panic or a silent partial parse.

use std::path::PathBuf;

use taxbreak::prop_assert;
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{decompose::decompose, phase2, Phase1, ReplayConfig, SimReplayBackend};
use taxbreak::trace::binary::{self, BinaryTraceError, BinaryTraceWriter, Dialect};
use taxbreak::trace::{
    EventKind, KernelMeta, ReplayArgs, Trace, TraceEvent, TraceMeta, TraceSink, Track,
};
use taxbreak::util::json::Json;
use taxbreak::util::prop::forall;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn golden_bytes(name: &str) -> Vec<u8> {
    let path = golden_dir().join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("taxbreak_trace_binary_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const GOLDEN: [&str; 4] = ["v1_min", "v2_multi", "v3_replay", "v4_fault"];

// -- golden corpus: byte stability in both directions -----------------------

#[test]
fn golden_json_is_canonical() {
    for name in GOLDEN {
        let bytes = golden_bytes(&format!("{name}.json"));
        let text = std::str::from_utf8(&bytes).unwrap();
        let trace = Trace::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(
            trace.to_json().dump().as_bytes(),
            bytes,
            "{name}.json is not byte-stable under parse → dump"
        );
    }
}

#[test]
fn golden_json_to_binary_reproduces_committed_bytes() {
    for name in GOLDEN {
        let text = String::from_utf8(golden_bytes(&format!("{name}.json"))).unwrap();
        let trace = Trace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(
            binary::encode(&trace),
            golden_bytes(&format!("{name}.tbt")),
            "{name}: JSON → binary drifted from the committed .tbt"
        );
    }
}

#[test]
fn golden_binary_to_json_reproduces_committed_bytes() {
    for name in GOLDEN {
        let tbt = golden_bytes(&format!("{name}.tbt"));
        let trace = binary::decode(&tbt).unwrap();
        assert_eq!(
            trace.to_json().dump().as_bytes(),
            golden_bytes(&format!("{name}.json")),
            "{name}: binary → JSON drifted from the committed .json"
        );
        // And the binary bytes themselves are a fixed point.
        assert_eq!(binary::encode(&trace), tbt, "{name}: decode → encode is not byte-stable");
    }
}

#[test]
fn golden_corpus_covers_every_spec_version() {
    // v1: no `device` field anywhere. v2: device-stamped, multi-stream.
    let v1 = binary::decode(&golden_bytes("v1_min.tbt")).unwrap();
    assert!(v1.events.iter().all(|e| e.device.is_none()));
    let v2 = binary::decode(&golden_bytes("v2_multi.tbt")).unwrap();
    assert!(v2.events.iter().any(|e| e.device == Some(1)));
    let streams: std::collections::BTreeSet<_> = v2
        .events
        .iter()
        .filter_map(|e| match e.track {
            Track::Device(s) => Some(s),
            Track::Host => None,
        })
        .collect();
    assert!(streams.len() > 1, "v2_multi must span multiple streams");
    // Wall is carried by the trailer and back-filled on read.
    assert_eq!(v2.meta.wall_us, 100.25);

    // v3: all four recording kinds present with their args payloads,
    // every recording event on correlation id 0.
    let v3 = binary::decode(&golden_bytes("v3_replay.tbt")).unwrap();
    for kind in [
        EventKind::Arrival,
        EventKind::RngDraw,
        EventKind::SchedDecision,
        EventKind::ClockJump,
    ] {
        let e = v3
            .events
            .iter()
            .find(|e| e.kind == kind)
            .unwrap_or_else(|| panic!("v3_replay lacks a {} event", kind.as_str()));
        assert_eq!(e.correlation_id, 0, "{} must carry corr 0", kind.as_str());
        assert_eq!(e.args.is_some(), kind.has_args());
    }
    match v3
        .events
        .iter()
        .find_map(|e| match &e.args {
            Some(ReplayArgs::SchedDecision { admitted, .. }) => Some(admitted),
            _ => None,
        }) {
        Some(admitted) => assert_eq!(
            admitted,
            &vec![vec![0, 2], vec![1]],
            "group boundaries survive the round trip"
        ),
        None => panic!("v3_replay lacks a sched_decision args payload"),
    }

    // v4: one fault event per window kind, each re-armable from args
    // (corr 0, device-stamped), plus sched decisions with a populated
    // and an empty shed list.
    let v4 = binary::decode(&golden_bytes("v4_fault.tbt")).unwrap();
    let fault_kinds: Vec<&str> = v4
        .events
        .iter()
        .filter_map(|e| match &e.args {
            Some(ReplayArgs::Fault { kind, .. }) => {
                assert_eq!(e.kind, EventKind::Fault);
                assert_eq!(e.correlation_id, 0, "fault events must carry corr 0");
                assert_eq!(e.device, Some(0), "fault events carry the replica stamp");
                Some(kind.as_str())
            }
            _ => None,
        })
        .collect();
    assert_eq!(
        fault_kinds,
        vec!["device_stall", "host_jitter", "launch_fail", "kv_pressure"],
        "v4_fault must cover every fault kind"
    );
    let sheds: Vec<&Vec<u64>> = v4
        .events
        .iter()
        .filter_map(|e| match &e.args {
            Some(ReplayArgs::SchedDecision { shed, .. }) => Some(shed),
            _ => None,
        })
        .collect();
    assert_eq!(sheds, vec![&vec![3, 5], &vec![]], "v4_fault must pin both shed shapes");
}

#[test]
fn load_detects_dialect_by_magic_not_extension() {
    let dir = temp_dir("sniff");
    // Binary bytes behind a .json extension still load as binary.
    let lying = dir.join("actually_binary.json");
    std::fs::write(&lying, golden_bytes("v2_multi.tbt")).unwrap();
    let from_lying = Trace::load(&lying).unwrap();
    let from_json = Trace::from_json(
        &Json::parse(std::str::from_utf8(&golden_bytes("v2_multi.json")).unwrap()).unwrap(),
    )
    .unwrap();
    assert_eq!(from_lying, from_json);
}

#[test]
fn convert_round_trips_the_golden_corpus_byte_identically() {
    let dir = temp_dir("convert");
    for name in GOLDEN {
        let json_path = golden_dir().join(format!("{name}.json"));
        let tbt_path = golden_dir().join(format!("{name}.tbt"));
        // JSON → binary by output extension.
        let out_tbt = dir.join(format!("{name}.tbt"));
        let stats = binary::convert(&json_path, &out_tbt, None).unwrap();
        assert_eq!((stats.from, stats.to), (Dialect::Json, Dialect::Binary));
        assert_eq!(std::fs::read(&out_tbt).unwrap(), golden_bytes(&format!("{name}.tbt")));
        // Binary → JSON by output extension.
        let out_json = dir.join(format!("{name}.json"));
        let stats = binary::convert(&tbt_path, &out_json, None).unwrap();
        assert_eq!((stats.from, stats.to), (Dialect::Binary, Dialect::Json));
        assert_eq!(std::fs::read(&out_json).unwrap(), golden_bytes(&format!("{name}.json")));
        // Explicit --to overrides the extension.
        let out_any = dir.join(format!("{name}.trace"));
        let stats = binary::convert(&json_path, &out_any, Some(Dialect::Binary)).unwrap();
        assert_eq!(stats.to, Dialect::Binary);
        assert_eq!(std::fs::read(&out_any).unwrap(), golden_bytes(&format!("{name}.tbt")));
    }
}

#[test]
fn simulated_trace_save_load_save_is_byte_stable_in_binary() {
    let trace = simulate(
        &taxbreak::models::gpt2(),
        &taxbreak::hardware::Platform::h100(),
        &Workload::decode(1, 128, 2),
        7,
    );
    let dir = temp_dir("stability");
    let path = dir.join("sim.tbt");
    trace.save_auto(&path).unwrap();
    let first = std::fs::read(&path).unwrap();
    assert!(binary::is_binary(&first), ".tbt extension selects the binary dialect");
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded, trace);
    loaded.save_auto(&path).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), first, "save → load → save must be byte-stable");
}

// -- property tests ---------------------------------------------------------

fn arb_kernel_meta(g: &mut taxbreak::util::prop::Gen) -> KernelMeta {
    let names = ["k", "ampere_bf16_gemm", "moe_dispatch_ε", "void cutlass::Kernel<…>"];
    KernelMeta {
        kernel_name: (*g.choice(&names)).into(),
        family: (*g.choice(&["gemm_cublas", "elementwise", "moe_routing"])).into(),
        aten_op: (*g.choice(&["aten::mm", "aten::add", "aten::topk"])).into(),
        shapes_key: (*g.choice(&["f32[1]", "bf16[8,64]x[64,64]", ""])).into(),
        grid: [g.u64() as u32, g.usize_in(0, 9) as u32, 1],
        block: [g.usize_in(1, 1024) as u32, 1, g.u64() as u32],
        lib_mediated: g.bool(),
        flops: g.f64_in(0.0, 1e15),
        bytes: g.f64_in(0.0, 1e12),
    }
}

fn arb_trace(g: &mut taxbreak::util::prop::Gen) -> Trace {
    let mut t = Trace::new(TraceMeta {
        platform: g.choice(&["h100", "h200", ""]).to_string(),
        model: g.choice(&["gpt2", "olmoe-1b-7b", "m\"odel\n"]).to_string(),
        phase: g.choice(&["prefill", "decode", "serve"]).to_string(),
        batch: g.usize_in(0, 4096),
        seq: g.usize_in(0, 1 << 20),
        m_tokens: g.usize_in(0, 64),
        wall_us: g.f64_in(0.0, 1e9),
    });
    let kinds = EventKind::ALL;
    let names = ["e", "aten::mm", "decode.step \"q\"", "névtx\trange", ""];
    for _ in 0..g.usize_in(0, 20) {
        let kind = *g.choice(&kinds);
        t.push(TraceEvent {
            kind,
            name: g.choice(&names).to_string(),
            ts_us: g.f64_in(-1e6, 1e9),
            dur_us: g.f64_in(0.0, 1e7),
            // 53-bit ids: the JSON dialect stores numbers as f64, so
            // larger ids are not representable there (the binary-only
            // full-u64 range is covered by the bit-pattern test).
            correlation_id: g.u64() >> 11,
            track: if g.bool() {
                Track::Host
            } else {
                Track::Device(g.usize_in(0, u32::MAX as usize) as u32)
            },
            device: g.bool().then(|| g.usize_in(0, 255) as u32),
            // Spec-v3 kinds must carry their payload (readers in both
            // dialects reject an arrival/rng_draw/sched_decision
            // without one).
            args: match kind {
                EventKind::Arrival => Some(ReplayArgs::Arrival {
                    req: g.u64() >> 11,
                    plen: g.usize_in(0, 1 << 16) as u64,
                    max_new: g.usize_in(0, 4096) as u64,
                    model: g.choice(&["gpt2", "olmoe-1b-7b", ""]).to_string(),
                }),
                EventKind::RngDraw => Some(ReplayArgs::RngDraw {
                    site: g.choice(&["exec::decode_b8", "prep::null_kernel", ""]).to_string(),
                    value: g.f64_in(-1e9, 1e9),
                }),
                EventKind::SchedDecision => Some(ReplayArgs::SchedDecision {
                    step: g.u64() >> 11,
                    admitted: {
                        let groups = g.usize_in(0, 3);
                        (0..groups)
                            .map(|_| (0..g.usize_in(0, 4)).map(|_| g.u64() >> 11).collect())
                            .collect()
                    },
                    preempted: (0..g.usize_in(0, 4)).map(|_| g.u64() >> 11).collect(),
                    // Sometimes-empty: pins both the omitted-key (v3
                    // shape) and present-key (v4 shape) encodings.
                    shed: (0..g.usize_in(0, 3)).map(|_| g.u64() >> 11).collect(),
                    batch: g.usize_in(0, 256) as u64,
                }),
                EventKind::Fault => Some(ReplayArgs::Fault {
                    kind: g
                        .choice(&["device_stall", "host_jitter", "launch_fail", "kv_pressure"])
                        .to_string(),
                    target: g.choice(&["stream:0", "stream:*", "host:all", "launch", "kv"]).to_string(),
                    onset_us: g.f64_in(0.0, 1e9),
                    dur_us: g.f64_in(0.0, 1e7),
                    magnitude: g.f64_in(0.0, 64.0),
                }),
                _ => None,
            },
            meta: (kind == EventKind::Kernel && g.bool()).then(|| arb_kernel_meta(g)),
        });
    }
    t
}

#[test]
fn property_json_binary_json_round_trip_is_identity() {
    forall("json → binary → json round trip", 80, |g| {
        // Canonicalize through JSON first: the JSON dialect is the
        // source of truth and its number canonicalization (e.g.
        // integral floats printing as integers) is what byte equality
        // is defined over.
        let t = arb_trace(g);
        let canon = Trace::from_json(&Json::parse(&t.to_json().dump()).unwrap()).unwrap();
        let json1 = canon.to_json().dump();
        let bin = binary::encode(&canon);
        let back = match binary::decode(&bin) {
            Ok(b) => b,
            Err(e) => {
                g.fail(format!("decode failed: {e}"));
                return false;
            }
        };
        prop_assert!(g, back == canon, "binary round trip changed the trace");
        let json2 = back.to_json().dump();
        prop_assert!(g, json2 == json1, "JSON bytes changed across the dialect round trip");
        true
    });
}

#[test]
fn property_binary_preserves_f64_bit_patterns_json_cannot() {
    // Values the JSON dialect flattens (-0.0 prints as "0") or rejects
    // (non-finite) survive the binary dialect bit-for-bit.
    let mut t = Trace::new(TraceMeta { wall_us: f64::NAN, ..Default::default() });
    t.push(TraceEvent {
        kind: EventKind::Nvtx,
        name: "bits".into(),
        ts_us: -0.0,
        dur_us: f64::INFINITY,
        correlation_id: u64::MAX,
        track: Track::Device(u32::MAX),
        device: Some(u32::MAX),
        args: None,
        meta: None,
    });
    let back = binary::decode(&binary::encode(&t)).unwrap();
    assert_eq!(back.meta.wall_us.to_bits(), f64::NAN.to_bits());
    assert_eq!(back.events[0].ts_us.to_bits(), (-0.0f64).to_bits());
    assert_eq!(back.events[0].dur_us, f64::INFINITY);
    assert_eq!(back.events[0].correlation_id, u64::MAX);
    assert_eq!(back.events[0].track, Track::Device(u32::MAX));
    assert_eq!(back.events[0].device, Some(u32::MAX));
}

#[test]
fn decomposition_and_hdbi_agree_bit_for_bit_across_dialects() {
    let platform = taxbreak::hardware::Platform::h200();
    let trace = simulate(&taxbreak::models::gpt2(), &platform, &Workload::decode(2, 256, 3), 11);
    let dir = temp_dir("decomp");
    trace.save_auto(&dir.join("t.json")).unwrap();
    trace.save_auto(&dir.join("t.tbt")).unwrap();
    let from_json = Trace::load(&dir.join("t.json")).unwrap();
    let from_bin = Trace::load(&dir.join("t.tbt")).unwrap();
    assert_eq!(from_json, from_bin);

    let decompose_on = |t: &Trace| {
        let p1 = Phase1::from_trace(t);
        let mut backend = SimReplayBackend::new(platform.clone(), 13);
        let p2 = phase2::run(&p1.db, &mut backend, &ReplayConfig::fast());
        decompose(t, &p1, &p2)
    };
    let a = decompose_on(&from_json);
    let b = decompose_on(&from_bin);
    assert_eq!(a.dft_us().to_bits(), b.dft_us().to_bits());
    assert_eq!(a.orchestration_us().to_bits(), b.orchestration_us().to_bits());
    assert_eq!(a.hdbi().to_bits(), b.hdbi().to_bits(), "HDBI must agree bit-for-bit");
    assert_eq!(a.n_kernels, b.n_kernels);
}

// -- robustness: damage yields typed errors, never panics or silence --------

#[test]
fn every_truncation_is_a_typed_error_never_a_partial_parse() {
    let full = golden_bytes("v2_multi.tbt");
    for len in 0..full.len() {
        match binary::decode(&full[..len]) {
            Ok(_) => panic!("prefix of {len}/{} bytes parsed successfully", full.len()),
            Err(
                BinaryTraceError::Truncated(_)
                | BinaryTraceError::MissingTrailer
                | BinaryTraceError::BadMagic(_)
                | BinaryTraceError::Corrupt(_),
            ) => {}
            Err(other) => panic!("unexpected error class at prefix {len}: {other}"),
        }
    }
}

#[test]
fn property_salvage_recovers_a_whole_event_prefix_at_every_cut() {
    // The crash-salvage counterpart of the truncation test above:
    // cutting a valid stream at *every* byte offset either fails
    // (header/meta not yet intact — there is no trace to attach events
    // to) or recovers a whole-event prefix of the original, never a
    // partial event; only the intact buffer reports `complete`.
    // Generated traces (not goldens) so the corpus exercises the v4
    // fault/shed payloads too.
    forall("salvage at every truncation point", 12, |g| {
        let canon = Trace::from_json(
            &Json::parse(&arb_trace(g).to_json().dump()).unwrap(),
        )
        .unwrap();
        let full = binary::encode(&canon);
        for len in 0..=full.len() {
            let Ok(out) = binary::salvage(&full[..len]) else { continue };
            prop_assert!(
                g,
                out.recovered() <= canon.events.len()
                    && canon.events[..out.recovered()] == out.trace.events[..],
                "cut at {len}: salvage must yield a whole-event prefix"
            );
            prop_assert!(
                g,
                out.complete == (len == full.len()),
                "cut at {len}: only the intact buffer is complete"
            );
        }
        true
    });
}

#[test]
fn header_damage_is_reported_by_variant() {
    let full = golden_bytes("v1_min.tbt");
    let mut bad_magic = full.clone();
    bad_magic[0] = b'J';
    assert!(matches!(binary::decode(&bad_magic), Err(BinaryTraceError::BadMagic(_))));

    let mut bad_version = full.clone();
    bad_version[4] = 2;
    assert_eq!(
        binary::decode(&bad_version).unwrap_err(),
        BinaryTraceError::UnsupportedVersion(2)
    );

    let mut bad_flags = full.clone();
    bad_flags[6] = 1;
    assert_eq!(binary::decode(&bad_flags).unwrap_err(), BinaryTraceError::UnsupportedFlags(1));
}

#[test]
fn trailer_tampering_is_detected() {
    let full = golden_bytes("v1_min.tbt");
    let trailer_at = full.len() - binary::TRAILER_LEN;

    // Event count in the trailer disagrees with the stream.
    let mut miscounted = full.clone();
    miscounted[trailer_at + 1] = 99;
    assert_eq!(
        binary::decode(&miscounted).unwrap_err(),
        BinaryTraceError::CountMismatch { declared: 99, read: 5 }
    );

    // Broken end magic.
    let mut bad_end = full.clone();
    let n = bad_end.len();
    bad_end[n - 1] = b'X';
    assert!(matches!(binary::decode(&bad_end), Err(BinaryTraceError::Corrupt(_))));

    // Bytes after a valid trailer are an error, not silently ignored.
    let mut trailing = full.clone();
    trailing.push(0);
    assert!(matches!(binary::decode(&trailing), Err(BinaryTraceError::Corrupt(_))));
}

#[test]
fn convert_surfaces_reader_errors_without_panicking() {
    let dir = temp_dir("convert_err");
    let out = dir.join("out.json");

    let truncated = dir.join("truncated.tbt");
    let full = golden_bytes("v2_multi.tbt");
    std::fs::write(&truncated, &full[..full.len() / 2]).unwrap();
    let err = binary::convert(&truncated, &out, None).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");

    let versioned = dir.join("future.tbt");
    let mut bumped = full.clone();
    bumped[4] = 9;
    std::fs::write(&versioned, &bumped).unwrap();
    let err = binary::convert(&versioned, &out, None).unwrap_err();
    assert!(err.to_string().contains("version 9"), "{err}");

    let missing = dir.join("does_not_exist.tbt");
    assert!(binary::convert(&missing, &out, None).is_err());
}

// -- streaming writer: bounded memory ---------------------------------------

#[test]
fn streaming_writer_memory_is_o1_in_event_count() {
    let ev = TraceEvent {
        kind: EventKind::Kernel,
        name: "k".into(),
        ts_us: 1.0,
        dur_us: 2.0,
        correlation_id: 1,
        track: Track::Device(0),
        device: None,
        args: None,
        meta: None,
    };
    let peak_for = |n: usize| {
        let mut w = BinaryTraceWriter::new(std::io::sink(), &TraceMeta::default()).unwrap();
        for _ in 0..n {
            TraceSink::event(&mut w, &ev).unwrap();
        }
        TraceSink::finish(&mut w, 123.0).unwrap();
        assert_eq!(w.events_written(), n as u64);
        w.peak_buffered_bytes()
    };
    let small = peak_for(100);
    let large = peak_for(10_000);
    assert_eq!(small, large, "writer scratch must not grow with the event count");
    assert!(large < 4096, "one event's encoding should stay well under a page: {large}");
}

// -- spec drift: the documented constants are the implemented ones ----------

fn spec_text() -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("trace_format.md");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading spec {}: {e}", path.display()))
}

#[test]
fn spec_pins_the_binary_dialect_constants() {
    let spec = spec_text();
    assert!(spec.contains("## §10"), "spec must have a §10 binary dialect section");
    assert_eq!(binary::MAGIC, *b"TXBT");
    assert_eq!(binary::END_MAGIC, *b"TXBE");
    assert!(spec.contains("`TXBT`"), "spec must document the TXBT magic");
    assert!(spec.contains("`TXBE`"), "spec must document the TXBE end magic");
    assert!(
        spec.contains(&format!("dialect version {}", binary::VERSION)),
        "spec must pin the dialect version"
    );
    assert!(
        spec.contains(&format!("{}-byte trailer", binary::TRAILER_LEN)),
        "spec must pin the trailer length"
    );
    assert!(spec.contains("`.tbt`"), "spec must document the extension");
    assert_eq!(binary::EXTENSION, "tbt");
    for kind in EventKind::ALL {
        assert!(
            spec.contains(&format!("| `{}` | {} |", kind.as_str(), binary::kind_code(kind))),
            "spec §10 must map `{}` to wire code {}",
            kind.as_str(),
            binary::kind_code(kind)
        );
    }
}

// -- size claim + committed benchmark datapoint -----------------------------

#[test]
fn bundled_moe_decode_binary_is_at_least_30_percent_smaller_than_pretty_json() {
    let cfg = taxbreak::whatif::bundled::by_name("moe-decode").unwrap();
    let trace = simulate(
        &cfg.model_spec().unwrap(),
        &cfg.platform_spec().unwrap(),
        &cfg.workload(),
        cfg.seed,
    );
    let pretty = trace.to_json().pretty().len();
    let bin = binary::encode(&trace).len();
    assert!(
        (bin as f64) <= 0.7 * pretty as f64,
        "binary must be ≥30% smaller than pretty JSON: {bin} vs {pretty} bytes"
    );
}

#[test]
fn committed_bench_trace_datapoint_is_well_formed() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_trace.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let v = Json::parse(&text).unwrap();
    assert_eq!(v.str_of("bench").unwrap(), "trace");
    let events = v.usize_of("events").unwrap();
    assert!(events > 0);
    let bytes_of = |dialect: &str| v.req(dialect).unwrap().usize_of("bytes").unwrap();
    let (compact, pretty, bin) = (bytes_of("json_compact"), bytes_of("json_pretty"), bytes_of("binary"));
    assert!(bin < compact && compact < pretty);
    assert!(
        (bin as f64) <= 0.7 * pretty as f64,
        "committed datapoint must uphold the ≥30% size claim"
    );
    for dialect in ["json_compact", "json_pretty", "binary"] {
        let d = v.req(dialect).unwrap();
        let per_event = d.f64_of("bytes_per_event").unwrap();
        let expect = d.usize_of("bytes").unwrap() as f64 / events as f64;
        assert!((per_event - expect).abs() < 0.01, "{dialect}: bytes_per_event drifted");
    }
}
