//! Regression + property coverage for reservation-backed KV admission
//! (DESIGN.md §2).
//!
//! The seed scheduler *checked* worst-case KV demand at admission
//! (`padded_len + max_new_tokens`) but *allocated* only the prompt
//! pages, so a group admitted later could steal pages an earlier group
//! needed for decode, and the resulting `OutOfPages` was a fatal
//! mid-run error.  `deadlock_regression_*` reproduces exactly that
//! workload: it fails against the seed admission logic and passes with
//! reservations.

use taxbreak::prop_assert;
use taxbreak::serving::batcher::mock_backend::MockBackend;
use taxbreak::serving::{PagedKvManager, Request, Scheduler, SchedulerConfig};
use taxbreak::util::prop::forall;

fn request(id: u64, prompt_len: usize, max_new: usize) -> Request {
    Request {
        id,
        prompt: vec![3; prompt_len],
        max_new_tokens: max_new,
        arrival_us: 0.0,
    }
}

/// Two single-member groups against a 4-page pool, each needing 3
/// pages worst-case (prompt 16 + budget 32 at 16 tokens/page).
///
/// Seed behavior: group 0 admitted (worst 3 <= free 4) but only 1
/// prompt page allocated; group 1's check then also passes (3 <= 3),
/// and both groups run out of pages mid-decode at token 33 —
/// `run_to_completion` died with `out of KV pages`.  With reservations
/// group 1 waits, and both complete.
#[test]
fn deadlock_regression_two_groups_tight_kv() {
    let cfg = SchedulerConfig {
        max_batch: 1,
        max_groups: 2,
        kv_pages: 4,
        kv_page_tokens: 16,
        ..SchedulerConfig::default()
    };
    let mut s = Scheduler::new(MockBackend::new(), cfg);
    s.submit(request(0, 16, 32));
    s.submit(request(1, 16, 32));
    s.step().unwrap();
    assert_eq!(s.pending(), 2, "both requests still in flight");
    assert!(s.finished().is_empty());
    // Reservation-backed admission must serialize the two groups: the
    // second request's worst case (3 pages) cannot fit next to the
    // first's reservation.
    assert_eq!(s.active_group_shapes().len(), 1, "second group must wait");
    s.run_to_completion().unwrap();
    assert_eq!(s.finished().len(), 2);
    for f in s.finished() {
        assert_eq!(f.generated.len(), 32, "full decode budget delivered");
    }
    assert_eq!(s.kv.used_pages(), 0, "all pages reclaimed");
    assert_eq!(s.preemptions, 0, "reservations prevent backpressure entirely");
}

/// The same failure mode at the allocator level: check-only admission
/// (register prompt pages, extend later) deadlocks a pool that
/// reservations would have serialized.
#[test]
fn check_only_admission_exhausts_pool_reservations_do_not() {
    // Seed-style: both requests register prompt pages only.
    let mut kv = PagedKvManager::new(4, 16);
    kv.register(0, 16).unwrap();
    kv.register(1, 16).unwrap();
    kv.extend(0, 16).unwrap(); // token 32: page 2 of 2 free pages
    kv.extend(1, 16).unwrap();
    // Token 33 needs a 3rd page each — pool is dry: the seed scheduler
    // turned this into a fatal mid-run error.
    assert!(kv.extend(0, 1).is_err());

    // Reservation-backed: the second reserve is refused up front, the
    // first request decodes to its full budget untouched.
    let mut kv = PagedKvManager::new(4, 16);
    kv.reserve(0, 48).unwrap();
    assert!(kv.reserve(1, 48).is_err(), "admission control sees the true demand");
    kv.extend(0, 16).unwrap();
    kv.extend(0, 32).unwrap(); // full budget, covered by the reservation
    assert_eq!(kv.release(0).unwrap(), 3);
    kv.reserve(1, 48).unwrap();
    kv.check_invariants().unwrap();
}

/// Random reserve/extend/release_excess/release op sequences hold the
/// allocator invariants — in particular release_excess followed by
/// further extends (which then draw from the free pool) never
/// double-allocates or leaks.
#[test]
fn prop_reservation_ops_hold_invariants() {
    forall("reserve/extend/release_excess invariants", 40, |g| {
        let pages = g.usize_in(4, 32);
        let mut kv = PagedKvManager::new(pages, 16);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..30 {
            match g.usize_in(0, 3) {
                0 => {
                    let tokens = g.usize_in(1, 64);
                    if kv.reserve(next_id, tokens).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let idx = g.usize_in(0, live.len() - 1);
                    let _ = kv.extend(live[idx], g.usize_in(1, 24));
                }
                2 if !live.is_empty() => {
                    let idx = g.usize_in(0, live.len() - 1);
                    prop_assert!(
                        g,
                        kv.release_excess(live[idx]).is_ok(),
                        "release_excess failed"
                    );
                }
                _ if !live.is_empty() => {
                    let idx = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    prop_assert!(g, kv.release(id).is_ok(), "release failed");
                }
                _ => {}
            }
            prop_assert!(g, kv.check_invariants().is_ok(), "invariants broken");
        }
        for id in live {
            let _ = kv.release(id);
        }
        kv.used_pages() == 0
    });
}

/// Randomized workloads: every configuration in this space is
/// admissible (worst-case single request = 4 pages <= min pool), so
/// runs must never error, KV invariants must hold throughout, and
/// every request must get its exact decode budget.
#[test]
fn prop_randomized_workloads_complete_without_errors() {
    forall("reservation admission serves every workload", 60, |g| {
        let n = g.usize_in(1, 24);
        let max_batch = g.usize_in(1, 4);
        let max_groups = g.usize_in(1, 4);
        let kv_pages = g.usize_in(4, 40);
        let cfg = SchedulerConfig {
            max_batch,
            max_groups,
            kv_pages,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = Scheduler::new(MockBackend::new(), cfg);
        let mut budgets = Vec::new();
        for id in 0..n as u64 {
            let prompt_len = g.usize_in(1, 48);
            let max_new = g.usize_in(1, 12);
            let prompt = (0..prompt_len)
                .map(|_| g.raw_rng().below(250) as i32)
                .collect();
            budgets.push(max_new);
            s.submit(Request {
                id,
                prompt,
                max_new_tokens: max_new,
                arrival_us: 0.0,
            });
        }
        let run = s.run_to_completion();
        prop_assert!(g, run.is_ok(), "run errored: {:?}", run.err());
        prop_assert!(
            g,
            s.finished().len() == n,
            "finished {} != {n}",
            s.finished().len()
        );
        for f in s.finished() {
            prop_assert!(
                g,
                f.generated.len() == budgets[f.request.id as usize],
                "req {} generated {} != budget {}",
                f.request.id,
                f.generated.len(),
                budgets[f.request.id as usize]
            );
        }
        prop_assert!(g, s.kv.used_pages() == 0, "kv leak: {}", s.kv.used_pages());
        prop_assert!(g, s.preemptions == 0, "unexpected preemption");
        s.kv.check_invariants().is_ok()
    });
}
