//! Chaos property suite: arbitrary seeded fault plans through the full
//! serving stack (DESIGN.md §16).
//!
//! For storms of pseudo-random fault windows (`storm:SEED:N`) the run
//! must degrade *gracefully*, never wrongly:
//!
//! 1. it terminates (no deadlock — the stall guard would error, not
//!    hang, and even that must not fire);
//! 2. every request ends in exactly one outcome — completed, rejected,
//!    shed or failed — and the report's counters partition the request
//!    count;
//! 3. KV occupancy stays a valid fraction of the pool on every replica
//!    (pressure sequesters pages, it never mints or leaks them);
//! 4. the decomposition still partitions the captured trace: per-phase
//!    host/device totals equal the whole-trace split, fault events are
//!    decomposition-blind;
//! 5. record → replay → re-record is a byte-equal fixed point in both
//!    dialects — faults are replay-deterministic spec-v4 events, not
//!    noise.
//!
//! Plus the liveness rule pinned explicitly: a KV-pressure window that
//! sequesters the *whole* pool must not freeze an idle scheduler
//! (pressure applies only while groups are being served).

use taxbreak::prop_assert;
use taxbreak::serving::loadgen::{per_phase_split, run_sim_loadgen, LoadgenConfig};
use taxbreak::serving::{real_trace_split, replay, SchedulerConfig};
use taxbreak::trace::{binary, EventKind};
use taxbreak::util::prop::forall;

fn models(names: &[&str]) -> Vec<String> {
    names.iter().map(|s| s.to_string()).collect()
}

#[test]
fn property_storms_degrade_gracefully_and_replay_byte_identically() {
    forall("seeded fault storms", 8, |g| {
        let storm_seed = g.u64() >> 32;
        let n_windows = g.usize_in(1, 24);
        let devices = *g.choice(&[1, 1, 2]);
        let requests = g.usize_in(devices.max(4), 10);
        let cfg = LoadgenConfig {
            requests,
            rate_per_s: *g.choice(&[0.0, 2000.0]),
            devices,
            seed: g.u64() >> 32,
            sched: SchedulerConfig {
                kv_pages: g.usize_in(devices * 16, 64),
                ttft_deadline_us: *g.choice(&[0.0, 0.0, 4000.0]),
                tpot_deadline_us: *g.choice(&[0.0, 0.0, 800.0]),
                ..SchedulerConfig::default()
            },
            capture: true,
            faults: Some(format!("storm:{storm_seed}:{n_windows}")),
            ..LoadgenConfig::default()
        };
        let report = match run_sim_loadgen(&models(&["gpt2"]), "h200", &cfg) {
            Ok(r) => r,
            // Termination means *returning* — an error (e.g. the stall
            // guard) is as much a failure as a hang.
            Err(e) => {
                g.fail(format!("storm:{storm_seed}:{n_windows} errored: {e:#}"));
                return false;
            }
        };
        let run = &report.runs[0];

        // (2) exactly one outcome per request.
        let accounted = run.completed + run.rejected + run.sheds + run.failed;
        prop_assert!(
            g,
            accounted == requests,
            "outcomes must partition the {requests} requests: \
             {} completed + {} rejected + {} shed + {} failed = {accounted}",
            run.completed,
            run.rejected,
            run.sheds,
            run.failed
        );
        prop_assert!(
            g,
            run.deadline_misses <= run.completed,
            "only completed requests can miss a deadline"
        );

        // (3) KV conservation: occupancy is a fraction of each pool.
        for d in std::iter::once((run.kv_occupancy_mean, run.kv_occupancy_max))
            .chain(run.per_device.iter().map(|d| (d.kv_occupancy_mean, d.kv_occupancy_max)))
        {
            prop_assert!(
                g,
                (0.0..=1.0).contains(&d.0) && (0.0..=1.0).contains(&d.1) && d.0 <= d.1 + 1e-12,
                "KV occupancy must stay in [0, 1]: mean {} max {}",
                d.0,
                d.1
            );
        }

        // (4) the decomposition partitions the captured trace, faults
        // and all.
        let trace = run.trace.as_ref().expect("capture was requested");
        let n_faults = trace.events.iter().filter(|e| e.kind == EventKind::Fault).count();
        prop_assert!(
            g,
            n_faults == n_windows * devices,
            "every replica records the full {n_windows}-window plan, got {n_faults} fault events"
        );
        prop_assert!(
            g,
            trace
                .events
                .iter()
                .filter(|e| e.kind == EventKind::Fault)
                .all(|e| e.correlation_id == 0),
            "fault events ride correlation id 0 (decomposition-blind)"
        );
        let phases = per_phase_split(trace);
        let (host, dev, kernels) = real_trace_split(trace);
        let (p_host, p_dev, p_kernels) = phases.iter().fold((0.0, 0.0, 0), |acc, p| {
            (acc.0 + p.host_us, acc.1 + p.device_us, acc.2 + p.kernels)
        });
        prop_assert!(g, p_kernels == kernels, "phase split must cover every kernel");
        prop_assert!(
            g,
            (p_host - host).abs() < 1e-9 && (p_dev - dev).abs() < 1e-9,
            "per-phase totals must partition the whole-trace split"
        );

        // (5) replay fixed point, both dialects.
        let out = match replay(trace) {
            Ok(o) => o,
            Err(e) => {
                g.fail(format!("replay of the faulted capture errored: {e:#}"));
                return false;
            }
        };
        prop_assert!(
            g,
            out.trace.events == trace.events && out.trace.meta == trace.meta,
            "replay must re-record the exact faulted event stream"
        );
        prop_assert!(
            g,
            out.trace.to_json().dump() == trace.to_json().dump(),
            "JSON dialect fixed point under faults"
        );
        prop_assert!(
            g,
            binary::encode(&out.trace) == binary::encode(trace),
            "binary dialect fixed point under faults"
        );
        true
    });
}

#[test]
fn full_pool_sequestration_cannot_deadlock_an_idle_scheduler() {
    // `kv:0:1e9:1.0` hides the *entire* pool for the whole run. If
    // pressure applied while the scheduler is idle, no request could
    // ever be admitted, the virtual clock (which only advances through
    // backend work) would freeze, and the run would deadlock. The
    // liveness rule — pressure acts only while groups are being served
    // — makes the run terminate with every request accounted for.
    let cfg = LoadgenConfig {
        requests: 6,
        rate_per_s: 0.0,
        capture: true,
        faults: Some("kv:0:1000000000:1.0".to_string()),
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&models(&["gpt2"]), "h200", &cfg).unwrap();
    let run = &report.runs[0];
    assert_eq!(
        run.completed + run.rejected + run.sheds + run.failed,
        6,
        "the fully-sequestered run must terminate with every request accounted for"
    );
    assert!(run.completed > 0, "an idle scheduler admits from the real pool");
}

#[test]
fn mixed_fault_plan_under_deadlines_keeps_the_counters_consistent() {
    // A hand-built worst case: stall + jitter + launch failures + KV
    // pressure all overlapping, with tight deadlines. The run must
    // terminate, count every request exactly once, and report the
    // degradation through the typed counters rather than erroring.
    let cfg = LoadgenConfig {
        requests: 10,
        rate_per_s: 3000.0,
        sched: SchedulerConfig {
            kv_pages: 24,
            ttft_deadline_us: 2500.0,
            tpot_deadline_us: 400.0,
            ..SchedulerConfig::default()
        },
        capture: true,
        faults: Some(
            "stall:0:40000:6.0;jitter:0:40000:3.0:all;launchfail:0:20000:2;kv:0:30000:0.75"
                .to_string(),
        ),
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&models(&["gpt2"]), "h200", &cfg).unwrap();
    let run = &report.runs[0];
    assert_eq!(run.completed + run.rejected + run.sheds + run.failed, 10);
    assert!(run.retries > 0, "launch-fail windows must charge retries");
    // The capture still replays byte-identically even at this severity.
    let trace = run.trace.as_ref().unwrap();
    let out = replay(trace).unwrap();
    assert_eq!(out.trace.events, trace.events);
    assert_eq!(binary::encode(&out.trace), binary::encode(trace));
}
