//! Spec-drift lock for the bench trajectory (`docs/bench.md`).
//!
//! The committed `BENCH_*.json` datapoints are a contract between the
//! CLI (`taxbreak bench-trace`, `taxbreak loadgen --bench-out`), the
//! CI regression guard (`scripts/check_bench.py`) and whoever reads
//! the trajectory. Mirroring the `docs/metrics.md` test in
//! `tests/obs.rs`: every field a datapoint can carry is named below,
//! the doc must document each one, every field the doc's tables name
//! must exist here, and the fields `LoadgenReport::bench_json` emits
//! at runtime must all be documented.

use std::path::PathBuf;

use taxbreak::serving::{run_sim_loadgen, LoadgenConfig};
use taxbreak::util::json::Json;

/// Every field the three bench datapoints can carry.  Adding, renaming
/// or dropping a field must update both this list and `docs/bench.md`,
/// or this test fails.  (The `replay` object and the trace-codec
/// fields are assembled in `main.rs`; their names are pinned here and
/// by the CI smoke's greps.)
const BENCH_FIELDS: [&str; 38] = [
    // shared envelope
    "bench",
    "source",
    // BENCH_trace.json (taxbreak bench-trace)
    "events",
    "runs",
    "json_compact",
    "json_pretty",
    "binary",
    "bytes",
    "bytes_per_event",
    "encode_events_per_s",
    "decode_events_per_s",
    "binary_vs_pretty_json",
    "binary_vs_compact_json",
    // BENCH_loadgen.json / BENCH_timeline.json (loadgen --bench-out)
    "platform",
    "requests",
    "devices",
    "streams",
    "intern_hits",
    "intern_misses",
    "throughput_tps",
    "tpot_p50_us",
    "tpot_p99_us",
    "ttft_p99_us",
    "hdbi",
    "per_model",
    "model",
    "per_device",
    "device",
    "kv_occupancy_mean",
    // resilience KPIs (DESIGN.md §16): zero on fault-free runs
    "shed_rate",
    "retry_rate",
    "deadline_miss_p99_us",
    "replay",
    "tokens",
    "wall_s",
    "events_per_s",
    "tokens_per_s",
    "online_decompose_events_per_sec",
];

fn bench_doc() -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("docs")
        .join("bench.md");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

#[test]
fn bench_doc_names_every_field_and_nothing_else() {
    let doc = bench_doc();
    for name in BENCH_FIELDS {
        assert!(doc.contains(&format!("`{name}`")), "docs/bench.md is missing `{name}`");
    }
    // Every field a doc table's first column names is a real field:
    // rows look like "| `field` | meaning |" (several rows name a
    // field group, "| `a`, `b` | ...").
    for line in doc.lines() {
        let Some(rest) = line.strip_prefix("| `") else { continue };
        let Some(cell_end) = rest.find(" |") else { continue };
        for token in rest[..cell_end].split(", ") {
            let name = token.trim_matches('`');
            assert!(
                BENCH_FIELDS.contains(&name),
                "docs/bench.md documents unknown bench field `{name}`"
            );
        }
    }
}

/// Recursively collect object keys of a bench datapoint.
fn keys_of(j: &Json, out: &mut Vec<String>) {
    match j {
        Json::Obj(pairs) => {
            for (k, v) in pairs {
                out.push(k.clone());
                keys_of(v, out);
            }
        }
        Json::Arr(items) => {
            for v in items {
                keys_of(v, out);
            }
        }
        _ => {}
    }
}

#[test]
fn loadgen_bench_json_emits_only_documented_fields() {
    let cfg = LoadgenConfig {
        requests: 3,
        rate_per_s: 0.0,
        devices: 2,
        sched: taxbreak::serving::SchedulerConfig {
            kv_pages: 64,
            ..Default::default()
        },
        ..Default::default()
    };
    let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
    let bench = report.bench_json();
    let mut keys = Vec::new();
    keys_of(&bench, &mut keys);
    assert!(keys.contains(&"throughput_tps".to_string()));
    assert!(keys.contains(&"intern_hits".to_string()));
    for k in keys {
        assert!(
            BENCH_FIELDS.contains(&k.as_str()),
            "bench_json emits `{k}`, which docs/bench.md does not document"
        );
    }
}
