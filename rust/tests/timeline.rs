//! Integration: the unified discrete-event timeline engine.
//!
//! Three contracts:
//! 1. **Single-timeline equivalence** (the refactor's safety net): the
//!    engine with 1 host thread + 1 stream must reproduce the
//!    pre-refactor `Stream` + host-cursor semantics *bit-for-bit* — a
//!    property test over random op sequences, plus a golden test that
//!    re-implements the seed simulator loop verbatim and demands
//!    byte-identical trace JSON from today's `sim::simulate`.
//! 2. **Per-device decomposition**: the per-device slices partition the
//!    aggregate component-by-component, and the per-device HDBI is
//!    `hdbi_of` on each slice.
//! 3. **The scale-out question** (acceptance): on the bundled
//!    host-bound MoE decode point, `tensor-parallel:2` must predict a
//!    *smaller* end-to-end gain than `host-cpu` — adding a device
//!    multiplies launch-path cost instead of removing it.

use taxbreak::device::Stream;
use taxbreak::hardware::Platform;
use taxbreak::host::HostModel;
use taxbreak::kernels::cost;
use taxbreak::kernels::family::Family;
use taxbreak::lowering::{self, LowerOpts, PassKind};
use taxbreak::models::{self, ModelSpec};
use taxbreak::sim::{
    self, simulate, EXPERT_LOOP_US, PASS_CONST_US, PER_LAYER_US, Phase, SYNC_US, Workload,
};
use taxbreak::taxbreak::{analyze, ReplayConfig, SimReplayBackend};
use taxbreak::timeline::{Engine, StreamRef};
use taxbreak::trace::{EventKind, Trace, TraceEvent, TraceMeta, Track};
use taxbreak::util::prop::forall;
use taxbreak::util::rng::Rng;
use taxbreak::whatif::{self, parse_specs, Schedule};

// --- 1a. engine vs raw Stream + cursor: property test ------------------

#[test]
fn single_topology_engine_is_bit_identical_to_stream_plus_cursor() {
    forall("engine == stream+cursor", 200, |g| {
        let mut engine = Engine::single();
        let mut stream = Stream::new();
        let mut cursor = 0.0f64;

        let ops = g.usize_in(1, 40);
        for _ in 0..ops {
            match g.usize_in(0, 2) {
                0 => {
                    // Host occupies the dispatch thread.
                    let dur = g.f64_in(0.0, 50.0);
                    let (s, e) = engine.host_advance(0, dur);
                    let rs = cursor;
                    cursor += dur;
                    if s != rs || e != cursor {
                        g.fail(format!("advance drifted: {s} vs {rs}"));
                        return false;
                    }
                }
                1 => {
                    // Device sync wait (`t = t.max(sync_point())`).
                    engine.host_wait_until(0, engine.sync_point());
                    cursor = cursor.max(stream.sync_point());
                    if engine.host_now(0) != cursor {
                        g.fail("wait_until drifted".to_string());
                        return false;
                    }
                }
                _ => {
                    // Kernel submission off the current host cursor.
                    let gap = g.f64_in(0.0, 10.0);
                    let dur = g.f64_in(0.1, 80.0);
                    let a = engine.submit(StreamRef::PRIMARY, engine.host_now(0), gap, dur);
                    let b = stream.submit(cursor, gap, dur);
                    if a != b {
                        g.fail(format!("submit drifted: {a:?} vs {b:?}"));
                        return false;
                    }
                }
            }
        }
        engine.sync_point() == stream.sync_point()
            && engine.active_us() == stream.active_us()
            && engine.launched() == stream.launched()
            && engine.host_now(0) == cursor
    });
}

// --- 1b. golden: today's simulate == the pre-refactor loop -------------

/// The seed (pre-timeline-engine) simulator loop, reproduced verbatim
/// for the unmitigated eager path: serial host cursor + one FIFO
/// `Stream`. This pins the golden trace semantics: `sim::simulate`
/// refactors are only legal if they keep producing *these* bytes.
fn reference_simulate(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    seed: u64,
) -> Trace {
    let host = HostModel::new(platform.clone());
    let base = Rng::new(seed)
        .fork_str(&model.name)
        .fork_str(&platform.name);
    let mut host_rng = base.fork(1);
    let mut dev_rng = base.fork(2);
    let mut lower_rng = base.fork(3);

    let mut trace = Trace::new(TraceMeta {
        platform: platform.name.clone(),
        model: model.name.clone(),
        phase: workload.phase.as_str().to_string(),
        batch: workload.batch,
        seq: workload.seq,
        m_tokens: if workload.phase == Phase::Decode {
            workload.m_tokens
        } else {
            1
        },
        wall_us: 0.0,
    });

    let opts = LowerOpts {
        fused_attention: workload.fused_attention,
    };
    let st = platform.cpu.st_speed;
    let mut t = 0.0f64; // host cursor
    let mut stream = Stream::new();
    let mut corr: u64 = 0;

    let m = match workload.phase {
        Phase::Prefill => 1,
        Phase::Decode => workload.m_tokens.max(1),
    };
    let mut passes: Vec<(PassKind, usize, usize)> =
        vec![(PassKind::Prefill, workload.seq, workload.seq)];
    passes.extend((0..m - 1).map(|i| (PassKind::DecodeStep, 1, workload.seq + i + 1)));

    for (kind, seq_q, ctx) in passes {
        let mut glue = PASS_CONST_US + PER_LAYER_US * model.layers as f64;
        if let Some(moe) = &model.moe {
            glue += EXPERT_LOOP_US
                * (model.layers * (moe.n_experts + moe.shared_experts)) as f64;
        }
        t += glue / st;

        let seq = lowering::lower_pass(
            model,
            kind,
            workload.batch,
            seq_q,
            ctx,
            &opts,
            &mut lower_rng,
        );
        for meta in seq {
            corr += 1;
            let family = Family::from_tag(&meta.family).expect("lowering emits valid tags");
            let hs = host.sample(family, &mut host_rng);
            let dur = cost::sample_duration_us(
                family,
                meta.flops,
                meta.bytes,
                &platform.gpu,
                &mut dev_rng,
            );

            let torch_ts = t;
            let aten_ts = torch_ts + hs.t_py;
            let api_ts = aten_ts + hs.t_base + hs.t_ct;
            let api_end = api_ts + hs.api_dur;
            let timing = stream.submit(api_ts, hs.launch_gap, dur);
            t = api_end;

            trace.push(TraceEvent {
                kind: EventKind::TorchOp,
                name: format!("torch.{}", meta.aten_op.trim_start_matches("aten::")),
                ts_us: torch_ts,
                dur_us: api_end - torch_ts,
                correlation_id: corr,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            });
            trace.push(TraceEvent {
                kind: EventKind::AtenOp,
                name: meta.aten_op.to_string(),
                ts_us: aten_ts,
                dur_us: api_end - aten_ts,
                correlation_id: corr,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            });
            trace.push(TraceEvent {
                kind: EventKind::RuntimeApi,
                name: "cudaLaunchKernel".to_string(),
                ts_us: api_ts,
                dur_us: hs.api_dur,
                correlation_id: corr,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            });
            trace.push(TraceEvent {
                kind: EventKind::Kernel,
                name: meta.kernel_name.to_string(),
                ts_us: timing.start_us,
                dur_us: dur,
                correlation_id: corr,
                track: Track::Device(0),
                device: None,
                args: None,
                meta: Some(meta),
            });
        }

        t = t.max(stream.sync_point()) + SYNC_US / st;
    }

    trace.meta.wall_us = t.max(stream.sync_point());
    trace
}

#[test]
fn simulate_reproduces_the_pre_refactor_golden_traces_byte_for_byte() {
    for (model, wl, seed) in [
        (models::gpt2(), Workload::prefill(1, 128), 42u64),
        (models::gpt2(), Workload::decode(1, 64, 3), 7),
        (models::llama_1b(), Workload::prefill(4, 256), 11),
        (models::olmoe(), Workload::decode(1, 64, 2), 2026),
    ] {
        for platform in [Platform::h100(), Platform::h200()] {
            let engine_trace = simulate(&model, &platform, &wl, seed);
            let golden = reference_simulate(&model, &platform, &wl, seed);
            assert_eq!(
                engine_trace, golden,
                "{} on {}: the timeline engine must reproduce the \
                 pre-refactor trace exactly",
                model.name, platform.name
            );
            // Byte-identical on disk, not merely structurally equal.
            assert_eq!(
                engine_trace.to_json().dump(),
                golden.to_json().dump(),
                "{} on {}: golden trace bytes drifted",
                model.name,
                platform.name
            );
        }
    }
}

// --- 2. per-device decomposition ---------------------------------------

#[test]
fn per_device_slices_partition_the_aggregate_decomposition() {
    let model = models::llama_1b();
    let platform = Platform::h100();
    let wl = Workload::prefill(1, 128);
    let trace = sim::simulate_tensor_parallel(&model, &platform, &wl, 2, 5).unwrap();
    let mut backend = SimReplayBackend::new(platform, 9);
    let a = analyze(&trace, &mut backend, &ReplayConfig::fast());
    let d = &a.decomposition;

    assert_eq!(d.per_device.len(), 2, "one slice per rank");
    let sum = |f: fn(&taxbreak::taxbreak::DeviceSlice) -> f64| -> f64 {
        d.per_device.values().map(f).sum()
    };
    assert!((sum(|s| s.t_py_us) - d.t_py_us).abs() < 1e-6);
    assert!((sum(|s| s.t_base_us) - d.t_base_us).abs() < 1e-6);
    assert!((sum(|s| s.dct_us) - d.dct_us).abs() < 1e-6);
    assert!((sum(|s| s.dkt_us) - d.dkt_us).abs() < 1e-6);
    assert!((sum(|s| s.device_active_us) - d.device_active_us).abs() < 1e-6);
    let n: usize = d.per_device.values().map(|s| s.invocations).sum();
    assert_eq!(n, d.n_kernels);
    // Per-device HDBI is hdbi_of on the slice; SPMD ranks agree.
    for s in d.per_device.values() {
        let h = s.hdbi();
        assert!(h > 0.0 && h < 1.0);
        assert!(
            (h - taxbreak::taxbreak::hdbi_of(s.orchestration_us(), s.device_active_us))
                .abs()
                < 1e-12
        );
    }
    let hs: Vec<f64> = d.per_device.values().map(|s| s.hdbi()).collect();
    assert!((hs[0] - hs[1]).abs() < 1e-9, "symmetric ranks, equal HDBI");
    // Idle fraction is multi-device aware: available GPU time is
    // e2e × 2, so a host-heavy TP run must not clamp to 0% idle.
    let idle = d.idle_fraction();
    assert!(idle > 0.0 && idle < 1.0, "idle={idle}");
    assert!((idle + d.gpu_utilization() - 1.0).abs() < 1e-12);
}

// --- 3. the scale-out acceptance contrast ------------------------------

fn bundled_moe_schedule() -> Schedule {
    let cfg = whatif::bundled::moe_decode();
    let model = cfg.model_spec().unwrap();
    let platform = cfg.platform_spec().unwrap();
    let trace = simulate(&model, &platform, &cfg.workload(), cfg.seed);
    let mut backend = SimReplayBackend::new(platform, cfg.seed ^ 0x77);
    let a = analyze(&trace, &mut backend, &cfg.replay_config());
    Schedule::from_eager_trace(&trace, &a.phase2).unwrap()
}

#[test]
fn tensor_parallel_gains_less_than_a_faster_host_on_host_bound_moe() {
    let s = bundled_moe_schedule();

    let host = whatif::run(&s, &parse_specs(&["host-cpu:xeon-6538y".to_string()]).unwrap())
        .unwrap();
    let host_red = host
        .final_outcome()
        .reduction_vs(&host.baseline, |o| o.e2e_us);

    let tp = whatif::run(&s, &parse_specs(&["tensor-parallel:2".to_string()]).unwrap())
        .unwrap();
    let tp_red = tp.final_outcome().reduction_vs(&tp.baseline, |o| o.e2e_us);

    // The paper cannot answer this; the engine can: on the host-bound
    // MoE decode schedule a second GPU only helps the device-bound
    // prompt pass (expected-value model: ~3% e2e), while the faster
    // host CPU buys its 4-14% — the serial dispatch path still gates
    // every decode step. Scale-out is NOT the prescription here.
    assert!(
        tp_red < host_red,
        "tensor-parallel ({tp_red}) must gain less e2e than host-cpu ({host_red})"
    );
    assert!(
        (0.005..0.07).contains(&tp_red),
        "TP's gain is confined to the device-bound prompt pass, got {tp_red}"
    );
    // ...and it *multiplies* the launch path: every pass gained an
    // all-reduce launch on top of the untouched per-kernel dispatches.
    assert!(tp.final_outcome().n_kernels > tp.baseline.n_kernels);
    assert!(
        tp.final_outcome().orchestration_us() >= tp.baseline.orchestration_us(),
        "per-rank orchestration never shrinks under TP"
    );
}

// --- engine smoke through every consumer -------------------------------

#[test]
fn serving_whatif_and_sim_share_the_engine_clock_consistently() {
    // Serving identity: SimEngine wall == whatif synchronous replay.
    use taxbreak::runtime::{Backend, SimEngine};
    use taxbreak::serving::ModelBackend;
    let mut e = SimEngine::with_defaults(models::gpt2(), Platform::h200(), 5);
    let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
    let _ = e.decode_group(cache, 3, &next).unwrap();
    let trace = e.take_trace();
    let s = Schedule::from_serving_trace(&trace).unwrap();
    let out = whatif::resimulate(&s);
    let rel = (out.e2e_us - trace.meta.wall_us).abs() / trace.meta.wall_us;
    assert!(rel < 1e-9, "serving identity replay must stay exact: {rel}");

    // Eager identity: simulate -> schedule -> replay reproduces wall.
    let cfg = whatif::bundled::dense_prefill();
    let model = cfg.model_spec().unwrap();
    let platform = cfg.platform_spec().unwrap();
    let wl = Workload::prefill(1, 128);
    let tr = simulate(&model, &platform, &wl, 3);
    let mut backend = SimReplayBackend::new(platform, 4);
    let a = analyze(&tr, &mut backend, &ReplayConfig::fast());
    let es = Schedule::from_eager_trace(&tr, &a.phase2).unwrap();
    let eo = whatif::resimulate(&es);
    let rel = (eo.e2e_us - tr.meta.wall_us).abs() / tr.meta.wall_us;
    assert!(rel < 1e-3, "eager identity replay drifted: {rel}");
}
