//! Integration: `taxbreak whatif` — counterfactual replay.
//!
//! Pins the paper's headline prediction as a regression band (the
//! acceptance contrast): on the bundled host-bound MoE decode workload
//! the host-CPU counterfactual (H100 host → H200 host) must cut
//! T_Orchestration by 10-29% with an end-to-end improvement ≤ 14%,
//! while on the bundled device-bound dense prefill the same
//! counterfactual must be worth ~nothing end-to-end.

use taxbreak::config::RunConfig;
use taxbreak::sim::simulate;
use taxbreak::taxbreak::{analyze, Analysis, OptimizationTarget, SimReplayBackend};
use taxbreak::whatif::{self, parse_specs, Schedule};

fn analyze_bundled(cfg: &RunConfig) -> (Analysis, Schedule) {
    let model = cfg.model_spec().unwrap();
    let platform = cfg.platform_spec().unwrap();
    let trace = simulate(&model, &platform, &cfg.workload(), cfg.seed);
    let mut backend = SimReplayBackend::new(platform, cfg.seed ^ 0x77);
    let a = analyze(&trace, &mut backend, &cfg.replay_config());
    let s = Schedule::from_eager_trace(&trace, &a.phase2).unwrap();
    (a, s)
}

#[test]
fn host_cpu_counterfactual_matches_the_paper_bands_on_moe_decode() {
    let (a, s) = analyze_bundled(&whatif::bundled::moe_decode());
    assert!(
        a.decomposition.hdbi() < 0.5,
        "bundled MoE decode must be host-bound, HDBI={}",
        a.decomposition.hdbi()
    );

    let cfs = parse_specs(&["host-cpu:xeon-6538y".to_string()]).unwrap();
    let w = whatif::run(&s, &cfs).unwrap();
    let cf = w.final_outcome();
    let orch_red = cf.reduction_vs(&w.baseline, |o| o.orchestration_us());
    let e2e_red = cf.reduction_vs(&w.baseline, |o| o.e2e_us);

    // Paper §VI: faster host CPU => orchestration falls 10-29%.
    assert!(
        (0.10..=0.29).contains(&orch_red),
        "orchestration reduction {orch_red} outside the paper's 10-29% band"
    );
    // ... and end-to-end improves by up to 14% (meaningful but bounded).
    assert!(
        e2e_red <= 0.14,
        "e2e reduction {e2e_red} exceeds the paper's 14% ceiling"
    );
    assert!(
        e2e_red >= 0.04,
        "e2e reduction {e2e_red} implausibly small for a host-bound MoE run"
    );
    // Device work is untouched by a host-CPU swap.
    assert!(
        (cf.device_active_us - w.baseline.device_active_us).abs()
            < 1e-9 * w.baseline.device_active_us
    );
    // Host-bound + dispatch-dominated => the software stack is the
    // target, and the attached quantification cites a host counterfactual.
    assert_eq!(a.diagnosis.target, OptimizationTarget::SoftwareStack);
}

#[test]
fn host_cpu_counterfactual_is_worthless_on_device_bound_dense_prefill() {
    let (a, s) = analyze_bundled(&whatif::bundled::dense_prefill());
    assert!(
        a.decomposition.hdbi() > 0.6,
        "bundled dense prefill must be device-bound, HDBI={}",
        a.decomposition.hdbi()
    );
    assert_eq!(a.diagnosis.target, OptimizationTarget::DeviceWork);

    let cfs = parse_specs(&["host-cpu:xeon-6538y".to_string()]).unwrap();
    let w = whatif::run(&s, &cfs).unwrap();
    let e2e_red = w
        .final_outcome()
        .reduction_vs(&w.baseline, |o| o.e2e_us);
    assert!(
        e2e_red.abs() < 0.02,
        "device-bound prefill must be insensitive to the host CPU, got {e2e_red}"
    );
    // The orchestration *sum* still shrinks — the contrast is that the
    // schedule hides it behind device work.
    let orch_red = w
        .final_outcome()
        .reduction_vs(&w.baseline, |o| o.orchestration_us());
    assert!(orch_red > 0.10, "orch still falls: {orch_red}");
}

#[test]
fn quantified_diagnosis_backs_the_prescription_with_numbers() {
    let (mut a, s) = analyze_bundled(&whatif::bundled::moe_decode());
    whatif::quantify_diagnosis(&mut a, &s).unwrap();
    let q = a.diagnosis.quantified.as_ref().expect("quantified advice");
    assert!(q.counterfactual.starts_with("host-cpu:") || q.counterfactual == "lib-elision");
    assert!(q.orch_reduction > 0.05, "{q:?}");
    assert!(q.e2e_reduction > 0.0, "{q:?}");
}

#[test]
fn cuda_graphs_collapse_the_launch_floor_on_decode() {
    let cfg = RunConfig {
        model: "gpt2".to_string(),
        platform: "h100".to_string(),
        phase: taxbreak::sim::Phase::Decode,
        batch: 1,
        seq: 128,
        m_tokens: 6,
        warmup: 2,
        runs: 20,
        ..RunConfig::default()
    };
    let (_, s) = analyze_bundled(&cfg);
    let cfs = parse_specs(&["cuda-graphs".to_string()]).unwrap();
    let w = whatif::run(&s, &cfs).unwrap();
    let cf = w.final_outcome();
    // N·T_sys_floor collapses to ~one floor per graphed decode pass
    // (the eager capture pass keeps its per-kernel floors).
    assert!(
        cf.dkt_us < 0.5 * w.baseline.dkt_us,
        "dKT {} vs baseline {}",
        cf.dkt_us,
        w.baseline.dkt_us
    );
    assert!(cf.e2e_us < w.baseline.e2e_us, "graphs must shorten decode");
    assert_eq!(cf.n_kernels, w.baseline.n_kernels, "device work is preserved");
}

#[test]
fn captured_serving_run_replays_and_responds_to_host_scaling() {
    use taxbreak::serving::{run_sim_loadgen, LoadgenConfig};
    let cfg = LoadgenConfig {
        requests: 8,
        rate_per_s: 0.0,
        seed: 5,
        capture: true,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&["olmoe-1b-7b".to_string()], "h100", &cfg).unwrap();
    let trace = report.runs[0].trace.as_ref().expect("captured");
    let s = Schedule::from_serving_trace(trace).unwrap();

    // Identity fidelity: the replay reproduces the recorded wall-clock.
    let base = whatif::resimulate(&s);
    let rel = (base.e2e_us - trace.meta.wall_us).abs() / trace.meta.wall_us;
    assert!(rel < 1e-6, "serving identity replay drifted by {rel}");

    // Host scaling shortens the host-blocking serving schedule.
    let cfs = parse_specs(&["host-cpu:xeon-6538y".to_string()]).unwrap();
    let w = whatif::run(&s, &cfs).unwrap();
    let e2e_red = w.final_outcome().reduction_vs(&w.baseline, |o| o.e2e_us);
    assert!(e2e_red > 0.0, "host scaling must help a synchronous schedule");
    assert!(
        (w.final_outcome().device_active_us - w.baseline.device_active_us).abs() < 1e-9
    );
}

#[test]
fn composed_counterfactuals_report_progressively() {
    let cfg = RunConfig {
        model: "olmoe-1b-7b".to_string(),
        platform: "h100".to_string(),
        phase: taxbreak::sim::Phase::Decode,
        batch: 1,
        seq: 128,
        m_tokens: 3,
        warmup: 2,
        runs: 20,
        ..RunConfig::default()
    };
    let (_, s) = analyze_bundled(&cfg);
    let cfs = parse_specs(&[
        "lib-elision".to_string(),
        "fusion:moe:0.25".to_string(),
        "host-cpu:xeon-6538y".to_string(),
    ])
    .unwrap();
    let w = whatif::run(&s, &cfs).unwrap();
    assert_eq!(w.scenarios.len(), 3);
    // ΔCT vanishes at stage 1 and stays gone.
    assert_eq!(w.scenarios[0].outcome.dct_us, 0.0);
    assert_eq!(w.scenarios[2].outcome.dct_us, 0.0);
    // MoE dispatch reduction shrinks the launch count at stage 2.
    assert!(w.scenarios[1].outcome.n_kernels < w.baseline.n_kernels / 2);
    // Each stage composes on the previous: e2e is monotone here.
    let e = [
        w.baseline.e2e_us,
        w.scenarios[0].outcome.e2e_us,
        w.scenarios[1].outcome.e2e_us,
        w.scenarios[2].outcome.e2e_us,
    ];
    for pair in e.windows(2) {
        assert!(pair[1] <= pair[0] * (1.0 + 1e-9), "{e:?}");
    }
    // The rendered report carries every scenario row.
    let table = whatif::report::whatif_table(&w).render();
    for label in ["baseline", "+lib-elision", "+fusion:moe:0.25", "+host-cpu:xeon-6538y"] {
        assert!(table.contains(label), "missing {label}:\n{table}");
    }
}
