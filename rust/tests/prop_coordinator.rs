//! Property-based tests on coordinator invariants (routing, batching,
//! KV state) and on the TaxBreak decomposition algebra, using the
//! in-tree `util::prop` harness (proptest substitute).

use std::collections::HashMap;

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::prop_assert;
use taxbreak::serving::batcher::mock_backend::MockBackend;
use taxbreak::serving::{PagedKvManager, Request, Scheduler, SchedulerConfig};
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{analyze, ReplayConfig, SimReplayBackend};
use taxbreak::util::prop::{forall, Gen};

fn random_requests(g: &mut Gen, n: usize, max_seq: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|id| {
            let prompt_len = g.usize_in(1, 48);
            let prompt = (0..prompt_len)
                .map(|_| g.raw_rng().below(251) as i32)
                .collect();
            let max_new = g.usize_in(1, (max_seq - prompt_len - 1).min(12).max(1));
            Request {
                id,
                prompt,
                max_new_tokens: max_new,
                arrival_us: 0.0,
            }
        })
        .collect()
}

#[test]
fn prop_scheduler_completes_every_request_exactly() {
    forall("scheduler completes all requests", 40, |g| {
        let n = g.usize_in(1, 20);
        let max_batch = g.usize_in(1, 6);
        let max_groups = g.usize_in(1, 3);
        let kv_pages = g.usize_in(24, 96);
        let cfg = SchedulerConfig {
            max_batch,
            max_groups,
            kv_pages,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = Scheduler::new(MockBackend::new(), cfg);
        let reqs = random_requests(g, n, 128);
        let budgets: HashMap<u64, usize> = reqs
            .iter()
            .map(|r| (r.id, r.max_new_tokens))
            .collect();
        for r in reqs {
            s.submit(r);
        }
        if s.run_to_completion().is_err() {
            // Permanently inadmissible configs (one request needs more
            // pages than exist) are allowed to error, not hang.
            return true;
        }
        prop_assert!(g, s.finished().len() == n, "finished {} != {n}", s.finished().len());
        for f in s.finished() {
            let want = budgets[&f.request.id];
            prop_assert!(
                g,
                f.generated.len() == want,
                "req {} generated {} != budget {want}",
                f.request.id,
                f.generated.len()
            );
        }
        prop_assert!(g, s.kv.used_pages() == 0, "kv leak: {}", s.kv.used_pages());
        s.kv.check_invariants().is_ok()
    });
}

#[test]
fn prop_kv_manager_never_double_allocates() {
    forall("kv pages disjoint under random ops", 60, |g| {
        let pages = g.usize_in(4, 64);
        let mut kv = PagedKvManager::new(pages, 16);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..40 {
            match g.usize_in(0, 2) {
                0 => {
                    let tokens = g.usize_in(1, 64);
                    if kv.register(next_id, tokens).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let idx = g.usize_in(0, live.len() - 1);
                    let _ = kv.extend(live[idx], g.usize_in(1, 16));
                }
                _ if !live.is_empty() => {
                    let idx = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    prop_assert!(g, kv.release(id).is_ok(), "release {id} failed");
                }
                _ => {}
            }
            prop_assert!(g, kv.check_invariants().is_ok(), "invariant broken");
            prop_assert!(
                g,
                kv.occupancy() <= 1.0 + 1e-9,
                "occupancy {} > 1",
                kv.occupancy()
            );
        }
        for id in live {
            let _ = kv.release(id);
        }
        kv.used_pages() == 0
    });
}

#[test]
fn prop_decomposition_algebra() {
    // Eq. 1-3 invariants on random workload points: components
    // non-negative, sum exactly to T_Orchestration, HDBI in (0,1),
    // per-family slices partition the totals.
    let platforms = Platform::all();
    let catalog = models::catalog();
    forall("decomposition algebra", 12, |g| {
        let model = &catalog[g.usize_in(0, catalog.len() - 1)];
        let platform = &platforms[g.usize_in(0, platforms.len() - 1)];
        let bs = *g.choice(&[1usize, 2, 4]);
        let sl = *g.choice(&[64usize, 128, 256]);
        let decode = g.bool();
        let wl = if decode {
            Workload::decode(bs, sl, g.usize_in(1, 3))
        } else {
            Workload::prefill(bs, sl)
        };
        let seed = g.u64();
        let trace = simulate(model, platform, &wl, seed);
        let mut backend = SimReplayBackend::new(platform.clone(), seed ^ 1);
        let a = analyze(&trace, &mut backend, &ReplayConfig::fast());
        let d = &a.decomposition;

        prop_assert!(g, d.t_py_us >= 0.0 && d.t_base_us >= 0.0, "negative component");
        prop_assert!(g, d.dct_us >= 0.0 && d.dkt_us >= 0.0, "negative component");
        let sum = d.dft_us() + d.dct_us + d.dkt_us;
        prop_assert!(
            g,
            (sum - d.orchestration_us()).abs() < 1e-6,
            "ME/CE violated: {sum} vs {}",
            d.orchestration_us()
        );
        let hdbi = d.hdbi();
        prop_assert!(g, hdbi > 0.0 && hdbi < 1.0, "hdbi {hdbi}");
        let fam_orch: f64 = d.per_family.values().map(|s| s.orchestration_us()).sum();
        prop_assert!(
            g,
            (fam_orch - d.orchestration_us()).abs() < 1e-6,
            "family slices don't partition"
        );
        let fam_n: usize = d.per_family.values().map(|s| s.invocations).sum();
        prop_assert!(g, fam_n == d.n_kernels, "family counts don't partition");
        // ΔCT must be zero exactly when the model is framework-native.
        if model.gemm_lib == models::GemmLib::Nvjet {
            prop_assert!(g, d.dct_us == 0.0, "nvjet model has dCT {}", d.dct_us);
        } else {
            prop_assert!(g, d.dct_us > 0.0, "cuBLAS model lost its dCT");
        }
        true
    });
}

#[test]
fn prop_simulation_determinism_and_seed_sensitivity() {
    let catalog = models::catalog();
    forall("sim deterministic per seed", 10, |g| {
        let model = &catalog[g.usize_in(0, catalog.len() - 1)];
        let p = Platform::h100();
        let wl = Workload::prefill(1, 128);
        let seed = g.u64();
        let a = simulate(model, &p, &wl, seed);
        let b = simulate(model, &p, &wl, seed);
        prop_assert!(g, a == b, "same seed must reproduce");
        let c = simulate(model, &p, &wl, seed ^ 0xFFFF);
        prop_assert!(
            g,
            (a.meta.wall_us - c.meta.wall_us).abs() > 1e-9,
            "different seed should perturb timings"
        );
        true
    });
}
