//! Integration: `serving::loadgen` — the arrival-driven load test of
//! the reservation-backed scheduler over the simulated engine.
//!
//! The headline case is the acceptance workload: 100 requests through
//! a mixed dense/MoE model set, completing without stalls, preemptions
//! or `OutOfPages`, and reporting TTFT/TPOT/HDBI.

use taxbreak::serving::loadgen::{per_phase_split, LenDist};
use taxbreak::serving::{run_sim_loadgen, LoadgenConfig};

fn models(names: &[&str]) -> Vec<String> {
    names.iter().map(|s| s.to_string()).collect()
}

#[test]
fn mixed_dense_moe_100_requests_complete_under_load() {
    let cfg = LoadgenConfig {
        requests: 100,
        rate_per_s: 2000.0,
        seed: 7,
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&models(&["gpt2", "olmoe-1b-7b"]), "h200", &cfg).unwrap();
    assert_eq!(report.runs.len(), 2);
    let dense = &report.runs[0];
    let moe = &report.runs[1];
    assert!(!dense.moe && moe.moe, "mix covers both model kinds");
    for run in &report.runs {
        assert_eq!(run.completed, 100, "{}: every request served", run.model);
        assert_eq!(run.rejected, 0, "{}: nothing unservable in a clamped workload", run.model);
        assert_eq!(run.preemptions, 0, "{}: no backpressure preemption", run.model);
        assert_eq!(run.late_arrivals, 0, "{}: virtual clock honors every arrival", run.model);
        assert_eq!(run.ttft_us.n, 100);
        assert!(run.tokens_generated >= 100, "at least one token each");
        assert!(run.throughput_tps() > 0.0);
        assert!(run.hdbi() > 0.0 && run.hdbi() < 1.0);
        assert!(run.kv_occupancy_mean > 0.0 && run.kv_occupancy_max <= 1.0);
        // Both serving phases observed, with per-phase HDBI defined.
        for phase in ["prefill", "decode"] {
            let p = run.phases.iter().find(|p| p.phase == phase).unwrap();
            assert!(p.kernels > 0, "{}: no {phase} kernels", run.model);
            assert!(p.hdbi() > 0.0 && p.hdbi() < 1.0);
        }
    }
    let rendered = report.render();
    for needle in ["TTFT", "TPOT", "HDBI", "gpt2", "olmoe-1b-7b", "prefill", "decode"] {
        assert!(rendered.contains(needle), "report missing {needle}:\n{rendered}");
    }
    let json = report.to_json().pretty();
    assert!(json.contains("ttft_p95_us") && json.contains("\"runs\""));
}

#[test]
fn loadgen_is_deterministic() {
    let cfg = LoadgenConfig {
        requests: 30,
        rate_per_s: 1500.0,
        seed: 11,
        ..LoadgenConfig::default()
    };
    let run = || {
        let r = run_sim_loadgen(&models(&["gpt2"]), "h100", &cfg).unwrap();
        let m = &r.runs[0];
        (
            m.completed,
            m.iterations,
            m.tokens_generated,
            m.wall_us,
            m.ttft_us.mean,
            m.tpot_us.mean,
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn closed_loop_and_open_loop_both_drain() {
    for rate in [0.0, 500.0] {
        let cfg = LoadgenConfig {
            requests: 20,
            rate_per_s: rate,
            prompt_len: LenDist::LogNormal { median: 20.0, sigma: 0.4 },
            seed: 3,
            ..LoadgenConfig::default()
        };
        let report = run_sim_loadgen(&models(&["llama-3.2-1b"]), "h200", &cfg).unwrap();
        assert_eq!(report.runs[0].completed, 20, "rate {rate}");
    }
}

#[test]
fn open_loop_arrivals_stretch_the_run() {
    // A slow arrival process must dominate wall time (the scheduler
    // waits for work), and TTFT stays bounded since the pool is idle.
    let slow = LoadgenConfig {
        requests: 10,
        rate_per_s: 100.0, // 10 ms mean inter-arrival
        seed: 5,
        ..LoadgenConfig::default()
    };
    let fast = LoadgenConfig {
        rate_per_s: 0.0,
        ..slow.clone()
    };
    let s = run_sim_loadgen(&models(&["gpt2"]), "h200", &slow).unwrap();
    let f = run_sim_loadgen(&models(&["gpt2"]), "h200", &fast).unwrap();
    assert!(
        s.runs[0].wall_us > f.runs[0].wall_us,
        "open loop {} us must exceed closed loop {} us",
        s.runs[0].wall_us,
        f.runs[0].wall_us
    );
}

#[test]
fn loadgen_rejects_bad_input() {
    use taxbreak::serving::SchedulerConfig;
    assert!(run_sim_loadgen(&[], "h200", &LoadgenConfig::default()).is_err());
    assert!(run_sim_loadgen(&models(&["gpt9"]), "h200", &LoadgenConfig::default()).is_err());
    assert!(run_sim_loadgen(&models(&["gpt2"]), "b300", &LoadgenConfig::default()).is_err());
    let zero = LoadgenConfig { requests: 0, ..LoadgenConfig::default() };
    assert!(run_sim_loadgen(&models(&["gpt2"]), "h200", &zero).is_err());
    // Degenerate scheduler knobs are rejected before they can panic
    // (kv_page_tokens = 0 divides by zero) or hang (kv_pages = 0).
    for sched in [
        SchedulerConfig { kv_page_tokens: 0, ..SchedulerConfig::default() },
        SchedulerConfig { kv_pages: 0, ..SchedulerConfig::default() },
        SchedulerConfig { max_batch: 0, ..SchedulerConfig::default() },
        SchedulerConfig { max_groups: 0, ..SchedulerConfig::default() },
    ] {
        let bad = LoadgenConfig { sched, ..LoadgenConfig::default() };
        assert!(run_sim_loadgen(&models(&["gpt2"]), "h200", &bad).is_err());
    }
}

#[test]
fn infeasible_requests_are_rejected_instead_of_hanging() {
    use taxbreak::serving::SchedulerConfig;
    // Every request needs >= pages_for(40 + 4) = 3 pages against a
    // 2-page pool: such requests can never be admitted, so they are
    // rejected at submit, the run completes (no hang, no stall), and
    // the report says so.
    let cfg = LoadgenConfig {
        requests: 2,
        prompt_len: LenDist::Uniform { lo: 40, hi: 48 },
        sched: SchedulerConfig { kv_pages: 2, ..SchedulerConfig::default() },
        ..LoadgenConfig::default()
    };
    let report = run_sim_loadgen(&models(&["gpt2"]), "h200", &cfg).unwrap();
    assert_eq!(report.runs[0].rejected, 2);
    assert_eq!(report.runs[0].completed, 0);
    assert!(report.render().contains("rejected as unservable"));
}

#[test]
fn per_phase_split_partitions_the_serve_trace() {
    use taxbreak::hardware::Platform;
    use taxbreak::models;
    use taxbreak::runtime::{Backend, SimEngine};
    use taxbreak::serving::ModelBackend;

    let mut e = SimEngine::with_defaults(models::gpt2(), Platform::h200(), 9);
    let (next, cache) = e.prefill_group(&[vec![1, 2, 3, 4]]).unwrap();
    let (next, cache) = e.decode_group(cache, 4, &next).unwrap();
    let _ = e.decode_group(cache, 5, &next).unwrap();
    let trace = e.take_trace();
    let phases = per_phase_split(&trace);
    let prefill = phases.iter().find(|p| p.phase == "prefill").unwrap();
    let decode = phases.iter().find(|p| p.phase == "decode").unwrap();
    assert_eq!(prefill.kernels, 1);
    assert_eq!(decode.kernels, 2);
    // The per-phase split must partition the whole-trace split.
    let (host, dev, n) = taxbreak::serving::real_trace_split(&trace);
    assert_eq!(prefill.kernels + decode.kernels, n);
    assert!((prefill.host_us + decode.host_us - host).abs() < 1e-9);
    assert!((prefill.device_us + decode.device_us - dev).abs() < 1e-9);
}
