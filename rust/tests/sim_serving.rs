//! Integration: the serving stack (scheduler + paged KV + batcher) over
//! the default simulated runtime backend — the offline analog of the
//! real-mode `real_runtime.rs` suite, exercising the same `Backend`
//! surface without PJRT.

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::runtime::{Backend, SimEngine, SimEngineConfig};
use taxbreak::serving::{run_sim_server_demo, serve_with};

#[test]
fn sim_serving_demo_end_to_end() {
    let s = run_sim_server_demo("gpt2", "h200", 6, 4, 99).unwrap();
    assert_eq!(s.requests, 6);
    assert!(s.tokens_generated >= 6 * 4);
    assert!(s.throughput_tps() > 0.0);
    assert!(s.ttft_us.mean > 0.0);
    assert!(s.wall_us > 0.0);
    assert!(s.hdbi() > 0.0 && s.hdbi() <= 1.0);
    assert!(s.executions > 0);
    assert_eq!(s.null_floor_us.n, 30);
    assert!(s.variant.starts_with("sim:"));
}

#[test]
fn sim_serving_is_deterministic() {
    let run = || {
        let s = run_sim_server_demo("llama-3.2-1b", "h100", 8, 4, 7).unwrap();
        (s.requests, s.iterations, s.tokens_generated, s.wall_us)
    };
    assert_eq!(run(), run());
}

#[test]
fn sim_serving_rejects_unknown_names() {
    assert!(run_sim_server_demo("gpt9", "h200", 2, 2, 1).is_err());
    assert!(run_sim_server_demo("gpt2", "b200", 2, 2, 1).is_err());
}

#[test]
fn serve_with_honors_custom_shape_grid() {
    let cfg = SimEngineConfig {
        vocab: 509,
        max_seq: 96,
        buckets: vec![2, 8],
        ..SimEngineConfig::default()
    };
    let engine = SimEngine::new(models::olmoe(), Platform::h100(), cfg, 11);
    let s = serve_with(engine, 10, 8, 3).unwrap();
    assert_eq!(s.requests, 10);
    assert!(s.tokens_generated > 0);
    // The null floor tracks the platform's GPU floor (H100 ~4.7 us).
    assert!((s.null_floor_us.mean - 4.72).abs() < 0.5, "{}", s.null_floor_us.mean);
}

#[test]
fn sim_backend_trace_survives_the_taxbreak_pipeline_shape_checks() {
    // The sim engine's trace is recorder-shaped: validate_trace accepts
    // it and the host/device split is well-formed.
    let mut e = SimEngine::with_defaults(models::gpt2(), Platform::h200(), 5);
    let prompts = vec![vec![1, 2, 3, 4], vec![5, 6]];
    let (next, cache) = taxbreak::serving::ModelBackend::prefill_group(&mut e, &prompts).unwrap();
    let _ = taxbreak::serving::ModelBackend::decode_group(&mut e, cache, 4, &next).unwrap();
    let trace = e.take_trace();
    taxbreak::taxbreak::phase1::validate_trace(&trace).unwrap();
    let (host, dev, n) = taxbreak::serving::real_trace_split(&trace);
    assert_eq!(n, 2);
    assert!(host > 0.0 && dev > 0.0);
    assert!(trace.meta.wall_us >= host + dev - 1e-6);
}
